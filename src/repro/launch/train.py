"""Production-style training driver with CPR as a first-class feature.

Trains a transformer LM (any registered arch, at full or reduced scale) on
the synthetic token pipeline, with CPR checkpointing the model-parallel
shard state (token-embedding rows + their optimizer rows — the Emb-PS
analogue) and optionally injecting failures to exercise partial recovery.

CLI:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 200 --batch 8 --seq 128 --mode cpr-mfu --failures 2
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.core import CPRManager, FailureInjector, SystemParams
from repro.core import trackers as trk
from repro.data.synthetic import TokenDataset
from repro.models import transformer as T
from repro.optim.optimizers import apply_updates, get_optimizer


def build_cfg(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = dataclasses.replace(cfg, num_layers=args.layers)
    return cfg


def train(cfg, steps=200, batch=8, seq=128, lr=0.005, mode="cpr-mfu",
          n_failures=2, fail_fraction=0.25, seed=0, target_pls=0.1,
          checkpoint_dir=None, log_every=20, use_flash=False,
          async_save=False, tracker_backend="pallas", sharded_save=False,
          delta_saves=None, n_emb=8, resume=False, writer_procs=False,
          readmit=False, transport=None, shard_addrs=None,
          heartbeat_interval=None, readmit_backoff=0.0, attach=False,
          resize_at=None, lease_ttl=None, parity_group_size=0,
          hash_backend="host", seg_size=512, transport_options=None):
    """Returns (final_params, history dict)."""
    assert cfg.causal and cfg.modality_frontend is None, \
        "LM driver needs a causal text model"
    params = T.init_model(cfg, jax.random.PRNGKey(seed))
    opt = get_optimizer("rowwise_adagrad", lr)
    ostate = opt.init(params)
    ds = TokenDataset(cfg.vocab_size, num_tokens=steps * batch * seq + 1,
                      seed=seed)

    # --- CPR over the Emb-PS analogue: the token-embedding rows ---
    p = SystemParams(T_total=float(steps),
                     T_fail=float(steps) / max(n_failures, 1), N_emb=n_emb)
    mgr = CPRManager(mode, p, (cfg.vocab_size,), target_pls=target_pls,
                     directory=checkpoint_dir, async_save=async_save,
                     tracker_backend=tracker_backend,
                     sharded_save=sharded_save, delta_saves=delta_saves,
                     writer_procs=writer_procs, readmit=readmit,
                     transport=transport, shard_addrs=shard_addrs,
                     heartbeat_interval=heartbeat_interval,
                     readmit_backoff=readmit_backoff, attach=attach,
                     lease_ttl=lease_ttl,
                     parity_group_size=parity_group_size,
                     hash_backend=hash_backend, seg_size=seg_size,
                     transport_options=transport_options)
    if resume and checkpoint_dir:
        # warm start from the last consistent cycle on disk: embedding rows,
        # their optimizer rows, and the non-embedding trainer tree
        from repro.core import load_latest_auto
        loaded = load_latest_auto(
            checkpoint_dir, [np.asarray(params["embed"])],
            [np.asarray(ostate["acc"]["embed"])], mgr.spec,
            trainer_state={k: v for k, v in params.items() if k != "embed"})
        r_t, r_a, trainer = loaded.restore_all()
        params = {**params, **(trainer or {}), "embed": jnp.asarray(r_t[0])}
        ostate = {**ostate,
                  "acc": {**ostate["acc"], "embed": jnp.asarray(r_a[0])}}
        if mgr.sharded_save and getattr(loaded, "spec", None) is not None:
            # the chain may have crossed a live resize: run under the
            # layout it last stamped, not the CLI's --n-emb
            mgr.adopt_layout(loaded.spec)
    tracker = mgr.tracker_init([params["embed"]])
    mgr.attach_store([params["embed"]], [ostate["acc"]["embed"]],
                     {k: v for k, v in params.items() if k != "embed"})
    if attach and checkpoint_dir and mgr.sharded_save:
        # coordinator failover: the store just took over the previous
        # coordinator's writer fleet at the last stamped cycle — warm the
        # trainer from it (adopted writers serve their reconciled images;
        # a poisoned shard falls back to its stamped disk state)
        r_t, r_a, trainer = mgr.store.restore_all()
        params = {**params, **(trainer or {}), "embed": jnp.asarray(r_t[0])}
        ostate = {**ostate,
                  "acc": {**ostate["acc"], "embed": jnp.asarray(r_a[0])}}
        rep = mgr.store.attach_report or {}
        print(f"attached to writer fleet: epoch={mgr.store.epoch} "
              f"cycle={rep.get('cycle')} adopted={rep.get('adopted')} "
              f"respawned={rep.get('respawned')} "
              f"poisoned={rep.get('poisoned')}", flush=True)
    inj = FailureInjector(n_failures, fail_fraction, p.N_emb, p.T_total,
                          seed=seed + 1)
    mgr.set_total_samples(steps * batch)
    is_mfu = mgr.is_priority and mode == "cpr-mfu"
    is_ssu = mgr.is_priority and mode == "cpr-ssu"

    @jax.jit
    def step_fn(params, ostate, tracker, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, batch, cfg, use_flash), has_aux=True)(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        updates, ostate = opt.update(grads, ostate, params)
        params = apply_updates(params, updates)
        if is_mfu:
            tracker = {0: trk.mfu_update(tracker[0], batch["tokens"])}
        elif is_ssu:
            tracker = {0: trk.ssu_update(tracker[0], batch["tokens"],
                                         mgr.ssu_period,
                                         backend=mgr.tracker_backend)}
        return params, ostate, tracker, loss

    history = {"loss": [], "events": []}
    t_sim = 0.0
    t0 = time.monotonic()           # duration timer, not a timestamp
    for i, b in enumerate(ds.batches(batch, seq, loop=True)):
        if i >= steps:
            break
        params, ostate, tracker, loss = step_fn(params, ostate, tracker, b)
        mgr.samples_seen += batch
        if i == 0:      # step 0 is jit compile; time the steady-state rate
            t_steady = time.monotonic()
            blocked0 = mgr.ledger.save_blocked_s
        else:           # exclude time already blocked inside save events
            train_wall = (time.monotonic() - t_steady) - \
                (mgr.ledger.save_blocked_s - blocked0)
            mgr.wall_time_scale = i / max(train_wall, 1e-9)
        t_prev, t_sim = t_sim, t_sim + 1.0
        if resize_at and i in resize_at:
            # live fleet resize under traffic: the reshard overlaps
            # training compute and the trainer joins it at the next save
            # boundary — no restart, at most one boundary's pause
            mgr.resize(resize_at[i], t_event=t_sim, step=i,
                       background=True)
            print(f"step {i:5d} resizing writer fleet -> "
                  f"{resize_at[i]} shards (reshard overlaps training)",
                  flush=True)
            history["events"].append(("resize", i, resize_at[i]))
        for t_ev in mgr.due_saves(t_sim):
            tracker = mgr.run_save(
                t_ev, [params["embed"]], [ostate["acc"]["embed"]], tracker,
                {k: v for k, v in params.items() if k != "embed"}, step=i)
            history["events"].append(("save", i))
        for ev in inj.between(t_prev, t_sim):
            new_t, new_a, info = mgr.on_failure(
                ev, [np.asarray(params["embed"])],
                [np.asarray(ostate["acc"]["embed"])])
            params = {**params, "embed": jnp.asarray(new_t[0])}
            # {**ostate, ...}: non-"acc" optimizer state must survive restores
            ostate = {**ostate,
                      "acc": {**ostate["acc"], "embed": jnp.asarray(new_a[0])}}
            history["events"].append(("failure", i, info.get("pls", 0.0)))
        if i % log_every == 0 or i == steps - 1:
            history["loss"].append((i, float(loss)))
            print(f"step {i:5d} loss {float(loss):.4f} "
                  f"({(time.monotonic() - t0) / (i + 1):.2f}s/step)",
                  flush=True)
    mgr.fence()   # drain in-flight async saves before reporting
    history["report"] = mgr.report()
    mgr.close()
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.005)
    ap.add_argument("--mode", default="cpr-mfu")
    ap.add_argument("--failures", type=int, default=2)
    ap.add_argument("--target-pls", type=float, default=0.1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--async-save", action="store_true",
                    help="background double-buffered checkpoint writer")
    ap.add_argument("--sharded-save", action="store_true",
                    help="one writer + directory per Emb-PS shard with a "
                         "coordinator fence (implies delta saves)")
    ap.add_argument("--no-delta-saves", action="store_true",
                    help="disable row-hash skip of unchanged rows in "
                         "sharded partial saves")
    ap.add_argument("--writer-procs", action="store_true",
                    help="run each shard writer in its own OS process "
                         "(crash-isolated; implies --sharded-save; alias "
                         "for --transport pipe)")
    ap.add_argument("--transport", choices=("inproc", "pipe", "socket"),
                    default=None,
                    help="writer-fleet transport: in-process applier "
                         "threads, per-shard OS processes (shared-memory "
                         "snapshots), or TCP to repro.launch.shard_server "
                         "hosts (implies --sharded-save unless inproc)")
    ap.add_argument("--shard-servers", default=None,
                    help="comma-separated host:port list, one per shard, "
                         "of externally launched shard_server hosts "
                         "(socket transport; default: auto-spawn local "
                         "loopback servers).  host:port*k assigns k "
                         "consecutive shards to one server and carries "
                         "them multiplexed over a single connection")
    ap.add_argument("--heartbeat-interval", type=float, default=None,
                    help="seconds between proactive writer liveness "
                         "probes (default: only discover dead writers at "
                         "submit/fence time)")
    ap.add_argument("--readmit-backoff", type=float, default=0.0,
                    help="base seconds of exponential re-admission "
                         "back-off for crash-looping shards (0 = retry "
                         "at every boundary)")
    ap.add_argument("--readmit", action="store_true",
                    help="respawn poisoned shard writers at the next cycle "
                         "boundary and reseed them (fresh full of their "
                         "current rows) instead of sticky fail-stop")
    ap.add_argument("--n-emb", type=int, default=8,
                    help="number of Emb-PS shards (N_emb)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the last consistent checkpoint cycle "
                         "from --checkpoint-dir before training")
    ap.add_argument("--attach", action="store_true",
                    help="standby-coordinator failover: take over the "
                         "previous coordinator's writer fleet recorded in "
                         "--checkpoint-dir/COORDINATOR (adopt running "
                         "shard_server writers under a new epoch, "
                         "reconcile to the last stamped cycle) and warm-"
                         "start the trainer from it; implies sharded save")
    ap.add_argument("--resize-at", action="append", default=None,
                    metavar="STEP:N",
                    help="live-resize the writer fleet to N shards at "
                         "training step STEP (repeatable, or one comma-"
                         "separated list; requires --sharded-save): the "
                         "coordinator fences, streams rows between "
                         "writers, and stamps a new layout epoch without "
                         "restarting training")
    ap.add_argument("--lease-ttl", type=float, default=None,
                    help="coordinator lease TTL in seconds: the active "
                         "coordinator renews a LEASE file in the "
                         "checkpoint dir each cycle; a standby's --attach "
                         "is refused while the lease is live (election "
                         "guard against split-brain takeover)")
    ap.add_argument("--parity-group-size", type=int, default=0,
                    help="XOR parity group size for the sharded writer "
                         "fleet (0 = off): peers carry running parity of "
                         "each other's updates so a crashed shard's "
                         "current image is reconstructed from survivors "
                         "(zero rollback) instead of replayed from its "
                         "last stamped cycle; under cpr-mfu the hottest "
                         "shards are re-grouped into half-size (stronger) "
                         "groups once tracker stats identify them")
    ap.add_argument("--tracker-backend", choices=("host", "pallas"),
                    default="pallas")
    ap.add_argument("--hash-backend", choices=("host", "pallas"),
                    default="host",
                    help="delta-save row-hash implementation: host numpy "
                         "loop or the Pallas FNV-1a kernel (bit-exact)")
    ap.add_argument("--seg-size", default="512",
                    help="tracker_select segment width (lane-aligned int), "
                         "or 'auto' to pick by measurement at startup "
                         "(the choice surfaces in the report)")
    ap.add_argument("--codec-level", type=int, default=0,
                    help="zlib level for large socket-transport frames "
                         "(0 = off); raw-vs-wire byte counters surface in "
                         "the report")
    ap.add_argument("--mux-group", type=int, default=0,
                    help="multiplex auto-spawned socket writers in groups "
                         "of this many shards per connection/server "
                         "(0 = one connection per shard; explicit "
                         "--shard-servers use host:port*k instead)")
    args = ap.parse_args()
    cfg = build_cfg(args)
    resize_at = None
    if args.resize_at:
        resize_at = {}
        for item in args.resize_at:
            for part in item.split(","):
                step_s, n_s = part.split(":")
                resize_at[int(step_s)] = int(n_s)
    shard_addrs = None
    mux = False
    if args.shard_servers:
        shard_addrs = []
        for hp in args.shard_servers.split(","):
            hp, star, mult = hp.partition("*")
            host, port = hp.rsplit(":", 1)
            k = int(mult) if star else 1
            if k > 1:           # k shards ride one multiplexed connection
                mux = True
            shard_addrs.extend([(host, int(port))] * k)
    transport_options = None
    if args.codec_level or mux or args.mux_group:
        transport_options = {}
        if args.codec_level:
            transport_options["codec_level"] = args.codec_level
        if mux:
            transport_options["mux"] = True
        if args.mux_group:
            transport_options["mux_group"] = args.mux_group
    seg_size = "auto" if args.seg_size == "auto" else int(args.seg_size)
    _, hist = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                    lr=args.lr, mode=args.mode, n_failures=args.failures,
                    target_pls=args.target_pls,
                    checkpoint_dir=args.checkpoint_dir,
                    async_save=args.async_save,
                    sharded_save=args.sharded_save,
                    delta_saves=(False if args.no_delta_saves else None),
                    n_emb=args.n_emb, resume=args.resume,
                    writer_procs=args.writer_procs, readmit=args.readmit,
                    transport=args.transport, shard_addrs=shard_addrs,
                    heartbeat_interval=args.heartbeat_interval,
                    readmit_backoff=args.readmit_backoff,
                    attach=args.attach, resize_at=resize_at,
                    lease_ttl=args.lease_ttl,
                    parity_group_size=args.parity_group_size,
                    tracker_backend=args.tracker_backend,
                    hash_backend=args.hash_backend, seg_size=seg_size,
                    transport_options=transport_options)
    r = hist["report"]
    o = r["overheads"]
    extra = ""
    if r.get("shard_failures") or r.get("shard_readmissions"):
        extra = (f" shard_failures={r['shard_failures']} "
                 f"readmissions={r['shard_readmissions']}")
    print(f"done: mode={r['mode']} pls={r['measured_pls']:.4f} "
          f"overhead={o['fraction'] * 100:.2f}% "
          f"save_blocked={o['save_blocked_s']:.3f}s "
          f"final_loss={hist['loss'][-1][1]:.4f}{extra}")


if __name__ == "__main__":
    main()
