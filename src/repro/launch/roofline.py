"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):
  compute    = HLO_FLOPs  / (chips × 197e12)
  memory     = HLO_bytes  / (chips × 819e9)
  collective = collective_bytes / (chips × 50e9)   [ICI; DCN for "pod" axis]

``cost_analysis`` counts a scan body ONCE (verified), so full-depth scanned
lowerings under-report.  We therefore lower two shallow *probes* (one and
two pattern-repetitions, both executing their layers inside a single scan
iteration) and extrapolate:  per_rep = cost(2) − cost(1);
total = cost(1) + (R−1)·per_rep (+ remainder·per_layer).

Collective bytes are not in cost_analysis at all: we parse the optimized
HLO text and sum operand bytes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute ops (excluding trivial scalar syncs), with
the same probe-diff extrapolation.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\],\s]+))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes per collective kind from HLO text."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "total": 0}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        nbytes = _shape_bytes(m.group(1))
        if nbytes <= 256:      # skip scalar/loop-counter syncs
            continue
        out[m.group(2)] += nbytes
        out["total"] += nbytes
    return out


@dataclass
class RooflineTerms:
    """All byte/flop quantities are PER CHIP: XLA SPMD emits one per-partition
    module, and ``cost_analysis``/HLO shapes describe that partition (verified
    against analytic totals: probe flops × 256 ≈ 6·N·D + attention terms)."""
    flops: float               # per-chip HLO flops
    hbm_bytes: float           # per-chip HLO bytes accessed
    coll_bytes: float          # per-chip collective payload bytes
    chips: int
    model_flops: float = 0.0   # analytic 6·N_active·D (global)

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        """MODEL_FLOPS / global HLO flops: fraction of compiled compute that
        is 'useful' 6·N·D work (catches remat/attention/redundancy)."""
        return (self.model_flops / (self.flops * self.chips)
                if self.flops else 0.0)

    def as_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def extrapolate(cost1: dict, cost2: dict, coll1: dict, coll2: dict,
                n_reps: int, rem_layers: int, pattern_len: int,
                chips: int, model_flops: float = 0.0) -> RooflineTerms:
    """probe1 = 1 repetition, probe2 = 2 repetitions of the block pattern."""
    f1, f2 = cost1.get("flops", 0.0), cost2.get("flops", 0.0)
    b1 = cost1.get("bytes accessed", 0.0)
    b2 = cost2.get("bytes accessed", 0.0)
    c1, c2 = coll1["total"], coll2["total"]
    per_rep = (max(f2 - f1, 0.0), max(b2 - b1, 0.0), max(c2 - c1, 0.0))
    scale = (n_reps - 1) + rem_layers / pattern_len
    return RooflineTerms(
        flops=f1 + per_rep[0] * scale,
        hbm_bytes=b1 + per_rep[1] * scale,
        coll_bytes=c1 + per_rep[2] * scale,
        chips=chips, model_flops=model_flops)


def analytic_model_flops(cfg, shape) -> float:
    """6·N_active·tokens for training; 2·N_active·tokens for inference."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens
