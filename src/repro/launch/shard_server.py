"""Remote shard-writer host: Emb-PS shard checkpoint writers over TCP.

Runs the same writer apply loop as the in-process / pipe transports
(``repro.core.transport.serve_shard``), but behind a TCP listener speaking
the length-prefixed frame protocol — so shard writers on *other hosts*
join the coordinator's DRAIN/STAMP fence.  The server itself is stateless
between connections: each accepted connection starts with a ``spawn``
message carrying the shard id, shard spec, directory and seed image, and
then becomes one writer incarnation.  Re-admission after a crash or
partition is simply a fresh connection with a fresh seed — the coordinator
drives it (``SocketEndpoint.respawn``).

The server never imports jax: it is numpy + sockets only, so it is cheap
to start and a trainer-side accelerator wedge cannot corrupt it.

CLI (one per writer host; the coordinator is pointed at them with
``train.py --transport socket --shard-servers host:port,...``)::

    PYTHONPATH=src python -m repro.launch.shard_server --host 0.0.0.0 \
        --port 7070

With ``--port 0`` the kernel picks a free port, printed on stdout as
``listening on <host>:<port>``.  The per-shard checkpoint directory named
in the ``spawn`` message is a *server-local* path: in a multi-host fleet,
point it at storage the recovery job can read (shared fs), or ship the
shard directories before running ``load_latest`` (docs/recovery.md).
"""
from __future__ import annotations

import argparse
import socket
import threading

from repro.core.checkpoint import EmbShardSpec
from repro.core.transport import SockChannel, serve_shard


def _handle_conn(sock: socket.socket):
    """One connection == one writer incarnation: read the spawn message,
    then run the shard apply loop until the peer goes away."""
    chan = SockChannel(sock)
    try:
        msg = chan.recv()
    except (EOFError, OSError):
        chan.close()
        return
    try:
        if msg[0] != "spawn":
            return
        (_, shard, table_sizes, n_shards, directory,
         seed_t, seed_a, seed_tr, fsync) = msg
        spec = EmbShardSpec(table_sizes, n_shards)
        serve_shard(chan, shard, spec, directory,
                    (seed_t, seed_a, seed_tr), fsync_payloads=fsync)
    finally:
        chan.close()


def serve(host: str = "127.0.0.1", port: int = 0, ready_cb=None,
          _accept_forever: bool = True) -> None:
    """Bind, listen, and serve writer connections until killed.  Each
    connection runs in its own thread (a host typically serves several
    shards of one fleet, plus re-admission reconnects)."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(64)
    bound = srv.getsockname()
    if ready_cb is not None:
        ready_cb(bound[0], bound[1])
    while True:
        try:
            conn, _ = srv.accept()
        except OSError:
            return
        t = threading.Thread(target=_handle_conn, args=(conn,),
                             name="cpr-shard-conn", daemon=True)
        t.start()
        if not _accept_forever:         # test hook: serve one connection
            return


def spawned_server_main(conn, host: str):
    """Auto-spawn entry point (``SocketEndpoint`` launches one loopback
    server per shard): bind port 0 and report the real address back over
    the bootstrap pipe before serving."""
    def ready(h, p):
        conn.send((h, p))
        conn.close()

    serve(host, 0, ready_cb=ready)


def main():
    ap = argparse.ArgumentParser(
        description="host remote CPR shard checkpoint writers")
    ap.add_argument("--host", default="0.0.0.0")
    ap.add_argument("--port", type=int, default=7070,
                    help="TCP port (0 = pick a free one)")
    args = ap.parse_args()

    def ready(h, p):
        print(f"listening on {h}:{p}", flush=True)

    serve(args.host, args.port, ready_cb=ready)


if __name__ == "__main__":
    main()
