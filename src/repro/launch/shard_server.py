"""Remote shard-writer host: Emb-PS shard checkpoint writers over TCP.

Runs the same writer apply loop as the in-process / pipe transports
(``repro.core.transport.WriterSession``), but behind a TCP listener
speaking the length-prefixed frame protocol — so shard writers on *other
hosts* join the coordinator's DRAIN/STAMP fence.

**Sessions outlive connections.**  Each accepted connection either
``spawn``s a fresh writer incarnation or ``attach``es to one the server
already holds: the server keeps a per-shard session registry, and a
session whose coordinator connection drops (trainer crash, partition) is
*parked* — image, durable watermark and latched-error state intact — until
a successor coordinator adopts it with the ``attach``/``reconcile``
handshake (``ShardedCheckpointWriter.attach``).  Takeover is guarded by
the monotonic coordinator **epoch**: an ``attach`` (or ``spawn``) carrying
an epoch no newer than the session's is answered ``("stale", ...)``, and a
still-connected stale coordinator's commands are rejected the same way —
an old coordinator that un-hangs can never submit or drain over its
successor.  Plain re-admission after a crash or partition by the *same*
coordinator remains a fresh connection + ``spawn`` with a fresh seed
(``SocketEndpoint.respawn``).

Sessions are also **donor/receiver endpoints for online fleet resize**
(``ShardedCheckpointWriter.resize``): inside a fence window the
coordinator streams row ranges out of donors with ``export`` frames,
swaps each retained session's store to the new layout epoch with a
``reshard`` frame (session and connection survive the resize), and ships
the stamped image back as a normal ``full`` save.  A coordinator that
cannot read a shard's directory at takeover sends ``rebuild`` instead of
``reconcile`` — the session then replays the shipped stamped-event plan
from its *own* local files (see ``repro.core.transport`` for the frames).

Sessions also hold the fleet's **XOR parity stripes** (``parity`` /
``parity-get`` frames): a session designated holder for a parity group
keeps the running XOR of its peer shards' images as soft in-memory state
— seeded by a ``("parity", epoch, seq, step, "full", ...)`` frame,
folded forward by ``"delta"`` frames shipped alongside row saves, and
read back by a recovering coordinator with ``parity-get`` to reconstruct
a crashed peer's *current* image from survivors (zero rollback).  Parity
state is deliberately not durable and not part of the stamped manifest:
it dies with the session, and the coordinator reseeds holders at
adoption/readmission.  All of this rides the shared ``WriterSession``
loop, so the frames behave identically over inproc, pipe and socket.

The server never imports jax: it is numpy + sockets only, so it is cheap
to start and a trainer-side accelerator wedge cannot corrupt it.

CLI (one per writer host; the coordinator is pointed at them with
``train.py --transport socket --shard-servers host:port,...``)::

    PYTHONPATH=src python -m repro.launch.shard_server --host 0.0.0.0 \
        --port 7070

With ``--port 0`` the kernel picks a free port, printed on stdout as
``listening on <host>:<port>``.  The per-shard checkpoint directory named
in the ``spawn`` / ``reconcile`` message is a *server-local* path: in a
multi-host fleet, point it at storage the recovery job can read (shared
fs), or ship the shard directories before running ``load_latest``
(docs/recovery.md).
"""
from __future__ import annotations

import argparse
import queue
import socket
import threading
from typing import Dict, Optional

from repro.analysis.protocol.spec import violation as _spec_violation
from repro.core.checkpoint import EmbShardSpec
from repro.core.transport import (ProtocolError, SockChannel,
                                  WriterSession, verify_shm_probe)


class SessionRegistry:
    """Per-server-process registry of live/parked writer sessions, keyed
    by shard id.  One host typically serves several shards of one fleet;
    the registry is what lets a successor coordinator adopt them."""

    def __init__(self):
        self.lock = threading.Lock()
        self.sessions: Dict[int, WriterSession] = {}  # guarded by: lock

    def spawn(self, shard: int, session: WriterSession,
              epoch: int) -> Optional[WriterSession]:
        """Install a fresh incarnation for ``shard`` (evicting any prior
        session's serve loops).  Returns None — or the existing session
        when the spawn is stale (its epoch is older than the session's:
        a superseded coordinator trying to respawn its lost writer)."""
        with self.lock:
            old = self.sessions.get(shard)
            if old is not None:
                if old.epoch > epoch:
                    return old
                old.evict()
            self.sessions[shard] = session
            return None

    def get(self, shard: int) -> Optional[WriterSession]:
        with self.lock:
            return self.sessions.get(shard)


def _serve_spawn(chan: SockChannel, registry: SessionRegistry, msg):
    """Handle a ``spawn`` command: fresh writer incarnation (stale spawns
    from a superseded coordinator are rejected)."""
    (_, shard, table_sizes, n_shards, directory,
     seed_t, seed_a, seed_tr, fsync) = msg[:9]
    epoch = msg[9] if len(msg) > 9 else 0
    boundaries = msg[10] if len(msg) > 10 else None
    old = registry.get(shard)
    if old is not None and old.epoch > epoch:
        # cheap pre-check before materializing the seed store (the
        # install below re-checks under the registry lock for the race)
        chan.send(("stale", "spawn", epoch, old.epoch))
        return
    spec = EmbShardSpec(table_sizes, n_shards, boundaries=boundaries)
    session = WriterSession(shard, spec, directory,
                            (seed_t, seed_a, seed_tr),
                            fsync_payloads=fsync, epoch=epoch)
    stale = registry.spawn(shard, session, epoch)
    if stale is not None:
        chan.send(("stale", "spawn", epoch, stale.epoch))
        return
    session.serve(chan, session.gen)


def _serve_attach(chan: SockChannel, registry: SessionRegistry, msg):
    """Handle the coordinator-failover handshake: adopt the shard's
    session for the (strictly newer) epoch, reconcile it against the last
    stamp, then serve.  Falls through to a plain spawn when the server
    holds no session for the shard (server restarted since)."""
    _, epoch, shard = msg
    session = registry.get(shard)
    if session is None:
        chan.send(("no-writer",))
        try:
            follow = chan.recv()
        except (EOFError, OSError, ProtocolError):
            return
        if _spec_violation(follow, state="attaching") is None \
                and follow[0] == "spawn":
            _serve_spawn(chan, registry, follow)
        return
    with session.lock:
        if session.epoch >= epoch:
            chan.send(("stale", "attach", epoch, session.epoch))
            return
        gen = session.claim(epoch)
        wm, err = session.watermark, session.err
    chan.send(("attach-ok", wm, err))
    try:
        rec = chan.recv()
    except (EOFError, OSError, ProtocolError):
        return                          # adopter vanished mid-handshake
    if _spec_violation(rec, state="attaching") is not None:
        return                          # hostile follow-up: drop, stay parked
    if rec[0] not in ("reconcile", "rebuild") or rec[1] != epoch:
        return
    with session.lock:
        if session.gen != gen or session.epoch != epoch:
            # an even newer coordinator claimed the session between our
            # attach-ok and this reconcile: this adopter is already stale
            chan.send(("stale", rec[0], epoch, session.epoch))
            return
        if rec[0] == "rebuild":
            # remote-disk reconcile: the adopter could not read this
            # shard's directory coordinator-side, so it ships the stamped
            # event plan and the session replays it from its OWN local
            # files (the same command the serve loop accepts)
            reply, _ = session._handle(rec)
        else:
            _, _, directory, watermark, seed_t, seed_a, seed_tr = rec
            seed = None if seed_t is None else (seed_t, seed_a, seed_tr)
            wm = session.reconcile(directory, watermark, seed)
            reply = ("reconciled", wm)
    chan.send(reply)
    session.serve(chan, gen)


class _ServerVirtChan:
    """Server side of one shard's virtual channel on a multiplexed
    connection: ``recv`` drains an inbox fed by the connection's demux
    loop, ``send`` wraps the reply in the ("mx", shard, frame) envelope
    (the shared channel's send lock serializes members).  Presents the
    same surface as ``SockChannel`` to the unchanged ``WriterSession``
    serve loop — so one shard blocked in a long apply cannot
    head-of-line-block a peer's DRAIN ack."""

    _EOF = object()

    def __init__(self, chan: SockChannel, shard: int):
        self._chan = chan
        self.shard = shard
        self._inbox: "queue.Queue" = queue.Queue()

    def deliver(self, msg):
        self._inbox.put(msg)

    def deliver_eof(self):
        self._inbox.put(self._EOF)

    def recv(self):
        msg = self._inbox.get()
        if msg is self._EOF:
            self._inbox.put(self._EOF)      # EOF is sticky
            raise EOFError("mux connection closed")
        return msg

    def send(self, msg):
        self._chan.send(("mx", self.shard, msg))

    def close(self):
        pass                                # lifetime == the connection's


def _serve_virtual(vchan: _ServerVirtChan, registry: SessionRegistry):
    """One shard's serve loop on a multiplexed connection — the first
    inner frame is the ordinary ``spawn`` / ``attach``."""
    try:
        msg = vchan.recv()
    except EOFError:
        return
    if _spec_violation(msg, state="negotiated") is not None:
        return      # hostile opener: this shard never gets a session
    if msg[0] == "spawn":
        _serve_spawn(vchan, registry, msg)
    elif msg[0] == "attach":
        _serve_attach(vchan, registry, msg)


def _serve_mux(chan: SockChannel, registry: SessionRegistry):
    """Demux loop for one multiplexed connection: routes each inbound
    ("mx", shard, frame) envelope to that shard's virtual channel,
    spinning up a per-shard serve thread on first sight.  Connection EOF
    parks every shard riding it (exactly the co-resident set)."""
    vchans: Dict[int, _ServerVirtChan] = {}
    threads = []
    try:
        while True:
            msg = chan.recv()
            if not (isinstance(msg, tuple) and msg and msg[0] == "mx"):
                continue                    # unknown envelope: drop
            if len(msg) != 3 or not isinstance(msg[1], int):
                # torn mx envelope: the whole connection is suspect —
                # sever it, parking exactly the co-resident shards
                raise ProtocolError(
                    f"malformed mx envelope (arity {len(msg)})")
            shard, inner = msg[1], msg[2]
            vc = vchans.get(shard)
            if vc is None:
                vc = _ServerVirtChan(chan, shard)
                vchans[shard] = vc
                t = threading.Thread(target=_serve_virtual,
                                     args=(vc, registry),
                                     name=f"cpr-shard-mux-{shard}",
                                     daemon=True)
                threads.append(t)
                t.start()
            vc.deliver(inner)
    except (EOFError, OSError, ValueError):
        pass
    finally:
        for vc in vchans.values():
            vc.deliver_eof()
        for t in threads:
            t.join(timeout=5.0)


def _handle_conn(sock: socket.socket, registry: SessionRegistry):
    """One connection == one coordinator's view of one shard writer (or,
    multiplexed, of several): an optional ``hello`` negotiates the
    per-frame codec / multiplexing / shm handoff, then the opening
    ``spawn`` / ``attach`` runs the apply loop until the peer goes away
    (parking the session) or a successor supersedes it."""
    chan = SockChannel(sock)
    try:
        msg = chan.recv()
    except (EOFError, OSError, ProtocolError):
        chan.close()
        return
    if _spec_violation(msg, state="start") is not None:
        # a frame that is not a legal opener (garbage bytes, session
        # command without a handshake): drop the connection before any
        # session state exists to damage
        chan.close()
        return
    try:
        if msg[0] == "hello":
            opts = msg[2] if len(msg) > 2 and isinstance(msg[2], dict) \
                else {}
            # shm handoff: prove we share the coordinator's machine by
            # attaching its probe segment and matching the nonce
            shm_ok = verify_shm_probe(opts.get("shm"))
            level = int(opts.get("codec_level") or 0)
            if level:
                floor = int(opts.get("codec_floor") or 0)
                chan.enable_codec(level, floor or None)
            chan.send(("hello-ok", {"shm": shm_ok}))
            if opts.get("mux"):
                _serve_mux(chan, registry)
                return
            try:
                msg = chan.recv()
            except (EOFError, OSError, ProtocolError):
                return
            if _spec_violation(msg, state="negotiated") is not None:
                return
        if msg[0] == "spawn":
            _serve_spawn(chan, registry, msg)
        elif msg[0] == "attach":
            _serve_attach(chan, registry, msg)
    # lint: allow[exception-hygiene] hostile handshake payloads (e.g.
    # codec_level="x") must drop the connection, not kill the accept
    # thread; sessions poison themselves inside serve()
    except (ProtocolError, ValueError, TypeError):
        pass
    finally:
        chan.close()


def serve(host: str = "127.0.0.1", port: int = 0, ready_cb=None,
          _accept_forever: bool = True) -> None:
    """Bind, listen, and serve writer connections until killed.  Each
    connection runs in its own thread (a host typically serves several
    shards of one fleet, plus re-admission reconnects and coordinator
    takeovers — all sharing this process's session registry)."""
    registry = SessionRegistry()
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(64)
    bound = srv.getsockname()
    if ready_cb is not None:
        ready_cb(bound[0], bound[1])
    while True:
        try:
            conn, _ = srv.accept()
        except OSError:
            return
        t = threading.Thread(target=_handle_conn, args=(conn, registry),
                             name="cpr-shard-conn", daemon=True)
        t.start()
        if not _accept_forever:         # test hook: serve one connection
            return


def spawned_server_main(conn, host: str):
    """Auto-spawn entry point (``SocketEndpoint`` launches one loopback
    server per shard): bind port 0 and report the real address back over
    the bootstrap pipe before serving."""
    def ready(h, p):
        conn.send((h, p))
        conn.close()

    serve(host, 0, ready_cb=ready)


def main():
    ap = argparse.ArgumentParser(
        description="host remote CPR shard checkpoint writers")
    ap.add_argument("--host", default="0.0.0.0")
    ap.add_argument("--port", type=int, default=7070,
                    help="TCP port (0 = pick a free one)")
    args = ap.parse_args()

    def ready(h, p):
        print(f"listening on {h}:{p}", flush=True)

    serve(args.host, args.port, ready_cb=ready)


if __name__ == "__main__":
    main()
