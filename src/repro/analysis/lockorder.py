"""Runtime lock-order sanitizer: fail on cycles in acquisition order.

Static lock-discipline checking (``repro.analysis.rules.locks``) is
lexical; it cannot see the *order* in which threads take locks at run
time.  This module is the dynamic half: ``install()`` monkeypatches
``threading.Lock``/``threading.RLock`` so that every lock constructed
*from repro source code* is wrapped in a tracking proxy.  Each
acquisition while other tracked locks are held records a directed edge
``held-site -> acquired-site`` in a global graph keyed by the lock's
construction site (file:line) — so all per-shard instances of, say,
``RemoteEndpoint._io_lock`` collapse into one node, and an ABBA order
between two lock *classes* is visible even when no single pair of
instances ever deadlocks in the observed run.

A cycle in that graph is a latent deadlock: some interleaving of the
observed threads can block forever.  ``find_cycle()`` returns one, and
the pytest fixture in ``tests/conftest.py`` (enabled with
``CPR_LOCK_SANITIZER=1``) asserts acyclicity after every test, so the
crash/failover/reshard suites double as race-detector workloads.

Notes and limits:

* Re-entrant acquisition of the *same instance* (RLock) adds no edge.
  Two **distinct** instances from the same construction site nested in
  one thread do add a self-edge — same-class nesting is exactly the
  ABBA-by-symmetry hazard.
* Only locks constructed while installed are tracked; locks internal to
  stdlib objects (queues, events) are untracked by the source-file
  filter.
* ``threading.Condition`` constructed from repro source is wrapped in
  :class:`_TrackedCondition`: its underlying lock is tracked like any
  other, and ``wait()`` models the release/reacquire pair — the lock
  leaves the held-stack while blocked and re-records ordering edges on
  wakeup.  Without this, a thread that holds lock A while a *condition*
  reacquires lock B on wakeup would hide an A->B edge (the
  ABBA-via-condition hazard: ``_MuxChan`` inboxes are exactly this
  shape).
"""
from __future__ import annotations

import _thread
import os
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple


class LockOrderError(AssertionError):
    """A cycle exists in the observed lock-acquisition graph."""


class _TrackedLock:
    """Proxy around a real Lock/RLock that reports to the sanitizer."""

    def __init__(self, inner, site: str, san: "LockOrderSanitizer"):
        self._inner = inner
        self.site = site
        self._san = san

    def acquire(self, blocking=True, timeout=-1):
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._san._note_acquire(self)
        return got

    def release(self):
        self._san._note_release(self)
        self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self):
        return self._inner.locked()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def __repr__(self):
        return f"<tracked {self._inner!r} from {self.site}>"


class _TrackedCondition:
    """Proxy around a real Condition whose lock is a tracked proxy.

    ``wait()`` is the interesting part: the real Condition releases and
    reacquires the underlying lock through private fast paths the
    sanitizer cannot see, so the proxy brackets the real wait with
    explicit release/acquire notes.  While blocked, the lock is off the
    thread's held-stack (true — wait released it); on wakeup the
    reacquisition records ordering edges against everything else the
    thread holds, exactly as a fresh ``acquire()`` would."""

    def __init__(self, inner, lockp: _TrackedLock,
                 san: "LockOrderSanitizer"):
        self._inner = inner             # real Condition over the real lock
        self._lockp = lockp             # tracked proxy over that same lock
        self._san = san
        self.site = lockp.site

    def acquire(self, *args, **kw):
        return self._lockp.acquire(*args, **kw)

    def release(self):
        self._lockp.release()

    def __enter__(self):
        self._lockp.acquire()
        return self

    def __exit__(self, *exc):
        self._lockp.release()
        return False

    def wait(self, timeout=None):
        self._san._note_release(self._lockp)
        try:
            return self._inner.wait(timeout)
        finally:
            self._san._note_acquire(self._lockp)

    def wait_for(self, predicate, timeout=None):
        # stdlib loop, re-expressed over the tracked wait()
        endtime = None
        waittime = timeout
        result = predicate()
        while not result:
            if waittime is not None:
                if endtime is None:
                    endtime = time.monotonic() + waittime
                else:
                    waittime = endtime - time.monotonic()
                    if waittime <= 0:
                        break
            self.wait(waittime)
            result = predicate()
        return result

    def notify(self, n=1):
        self._inner.notify(n)

    def notify_all(self):
        self._inner.notify_all()

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def __repr__(self):
        return f"<tracked {self._inner!r} from {self.site}>"


class LockOrderSanitizer:
    """Records per-thread lock nesting; detects acquisition-order cycles.

    ``package`` filters which construction sites get tracked (the frame
    that called ``threading.Lock()`` must live under ``<package>/``);
    pass ``package=None`` to track every construction, or skip
    ``install()`` entirely and wrap locks explicitly with ``wrap()``.
    """

    def __init__(self, package: Optional[str] = "repro"):
        self._package = package
        # (held_site, acquired_site) -> acquiring thread name (first seen)
        self._edges: Dict[Tuple[str, str], str] = {}
        # raw lock: the recorder must never route through a tracked lock
        self._mu = _thread.allocate_lock()
        self._tls = threading.local()
        self._orig = None
        self._orig_cond = None
        self.tracked_constructions = 0

    # -- wrapping -------------------------------------------------------
    def wrap(self, inner, site: str) -> _TrackedLock:
        self.tracked_constructions += 1
        return _TrackedLock(inner, site, self)

    def wrap_condition(self, lock, site: str) -> _TrackedCondition:
        """A tracked Condition: its lock joins the acquisition graph and
        ``wait()``'s release/reacquire pair is modeled (see
        :class:`_TrackedCondition`).  ``lock`` may be None (a fresh
        RLock, stdlib default), an already-tracked lock, or a raw one."""
        real_cond = (self._orig_cond if self._orig_cond is not None
                     else threading.Condition)
        if isinstance(lock, _TrackedLock):
            lockp = lock
        else:
            if lock is None:
                real_rlock = (self._orig[1] if self._orig is not None
                              else threading.RLock)
                lock = real_rlock()
            lockp = self.wrap(lock, site)
        return _TrackedCondition(real_cond(lockp._inner), lockp, self)

    def _site_of(self, frame) -> Optional[str]:
        fn = frame.f_code.co_filename.replace(os.sep, "/")
        if fn.endswith("/analysis/lockorder.py"):
            # a construction relayed through another (stacked) sanitizer's
            # factory: never track our own machinery, and leave the
            # filtering decision to the outermost factory's caller frame
            return None
        if self._package is not None:
            marker = f"/{self._package}/"
            if marker not in fn:
                return None
            fn = fn[fn.rindex(marker) + len(marker):]
        return f"{fn}:{frame.f_lineno}"

    def install(self):
        """Patch threading.Lock/RLock/Condition to return tracked
        objects for constructions originating in ``package`` source
        files."""
        if self._orig is not None:
            return
        real_lock, real_rlock = threading.Lock, threading.RLock
        real_cond = threading.Condition
        self._orig = (real_lock, real_rlock)
        self._orig_cond = real_cond

        def make(real):
            def factory():
                site = self._site_of(sys._getframe(1))
                if site is None:
                    return real()
                return self.wrap(real(), site)
            return factory

        def cond_factory(lock=None):
            site = self._site_of(sys._getframe(1))
            if site is None:
                return real_cond(lock)
            return self.wrap_condition(lock, site)

        threading.Lock = make(real_lock)
        threading.RLock = make(real_rlock)
        threading.Condition = cond_factory

    def uninstall(self):
        if self._orig is None:
            return
        threading.Lock, threading.RLock = self._orig
        threading.Condition = self._orig_cond
        self._orig = None
        self._orig_cond = None

    # -- recording ------------------------------------------------------
    def _held(self) -> List[_TrackedLock]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def _note_acquire(self, lock: _TrackedLock):
        stack = self._held()
        if not any(h is lock for h in stack):   # re-entrant: no new edges
            thread = threading.current_thread().name
            with self._mu:
                for held in stack:
                    self._edges.setdefault((held.site, lock.site), thread)
        stack.append(lock)

    def _note_release(self, lock: _TrackedLock):
        stack = self._held()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                return

    # -- reporting ------------------------------------------------------
    def edges(self) -> Dict[Tuple[str, str], str]:
        with self._mu:
            return dict(self._edges)

    def reset(self):
        with self._mu:
            self._edges.clear()

    def find_cycle(self) -> Optional[List[str]]:
        """One acquisition-order cycle as ``[a, b, ..., a]``, or None."""
        edges = self.edges()
        adj: Dict[str, List[str]] = {}
        for (a, b) in edges:
            adj.setdefault(a, []).append(b)
            adj.setdefault(b, [])
        state: Dict[str, int] = {}      # 0 absent / 1 on path / 2 done
        path: List[str] = []

        def dfs(node: str) -> Optional[List[str]]:
            state[node] = 1
            path.append(node)
            for nxt in adj[node]:
                if state.get(nxt, 0) == 1:
                    return path[path.index(nxt):] + [nxt]
                if state.get(nxt, 0) == 0:
                    cyc = dfs(nxt)
                    if cyc is not None:
                        return cyc
            path.pop()
            state[node] = 2
            return None

        for start in sorted(adj):
            if state.get(start, 0) == 0:
                cyc = dfs(start)
                if cyc is not None:
                    return cyc
        return None

    def assert_acyclic(self):
        cyc = self.find_cycle()
        if cyc is not None:
            edges = self.edges()
            detail = "\n".join(
                f"  {a} -> {b}   (thread {edges.get((a, b), '?')})"
                for a, b in zip(cyc, cyc[1:]))
            raise LockOrderError(
                "lock-order cycle (latent deadlock) in the acquisition "
                "graph:\n" + detail)
