"""Explicit-state model checker for the DRAIN/STAMP/takeover protocol.

A small abstraction of the system the spec describes — one primary
coordinator (C0), one standby (C1), N writers, and the durable disk
state (manifest cycle stamps + the COORDINATOR epoch file) — explored
exhaustively by breadth-first search over every interleaving of:

* save frames (per-coordinator, per-writer send / apply / parity fold),
* writer SIGKILLs (durable ``applied`` survives, soft parity stripes
  held *by* the dead writer vanish),
* coordinator takeover (standby claims ``disk_epoch + 1``, writes
  COORDINATOR, re-points every live writer's session epoch — the old
  primary keeps running: split-brain is a reachable state, the
  invariants say it must be harmless),
* stale rejections (a writer refusing a frame from a superseded epoch
  latches that coordinator's cycle),
* DRAIN barriers and STAMP appends (with the pre-STAMP COORDINATOR
  re-read guard).

Writers per coordinator *stream* track ``sent >= applied >= folded``:
``applied`` is the durable watermark (an ack in the wire protocol is
the durability receipt, so apply==ack here), ``folded`` is how much of
the writer's applied history its parity holder has absorbed.

The stamp-safety invariants checked at every transition:

  I1  a stamped cycle never references an unacked event
      (stamp watermark <= the stamping stream's durable ``applied``);
  I2  COORDINATOR epochs strictly increase on every disk write;
  I3  at most one stamper per epoch, and a stamp's epoch always equals
      the on-disk epoch at append time (the re-read guard's job);
  I4  parity reconstruction never adopts a stale stripe (an adopted
      stripe equals the victim's applied history exactly);
  I5  without a fresh stripe, recovery lands exactly on the last
      stamped cycle.

``MUTANTS`` are deliberately-seeded protocol bugs (drop the pre-STAMP
re-read, stamp the sent-not-acked watermark, adopt stale stripes, reuse
an epoch on takeover); ``--check`` proves the baseline clean and every
mutant caught, printing the counterexample trace.  Pure stdlib.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple


class Coord(NamedTuple):
    status: str            # active | standby | aborted | stale | done
    epoch: int
    sent: Tuple[int, ...]     # save frames sent, per writer
    applied: Tuple[int, ...]  # durably applied+acked, per writer
    folded: Tuple[int, ...]   # victim-j frames folded into j's holder


class State(NamedTuple):
    disk_epoch: int
    stamps: Tuple[Tuple[int, int, Tuple[int, ...]], ...]  # (epoch,c,wms)
    alive: Tuple[bool, ...]
    sess_epoch: Tuple[int, ...]
    coords: Tuple[Coord, ...]
    crashes: int
    takeovers: int


class Violation(NamedTuple):
    invariant: str
    message: str


class Scope(NamedTuple):
    n_writers: int = 2
    saves: Tuple[int, ...] = (2, 1)   # save frames per coordinator cycle
    max_crashes: int = 1
    max_takeovers: int = 1


FAST = Scope(saves=(1, 1))
FULL = Scope(saves=(2, 1))

MUTANTS = {
    "skip-stamp-reread":
        "STAMP without re-reading COORDINATOR: a superseded primary "
        "stamps after the standby's takeover (violates I3)",
    "stamp-unacked":
        "stamp the sent watermark without waiting for acks "
        "(violates I1)",
    "adopt-stale-stripe":
        "reconstruction adopts the surviving parity stripe without the "
        "freshness check (violates I4)",
    "reuse-epoch":
        "takeover claims disk_epoch instead of disk_epoch + 1 "
        "(violates I2)",
}


def _tset(t: tuple, i: int, v) -> tuple:
    return t[:i] + (v,) + t[i + 1:]


def initial_state(scope: Scope) -> State:
    n = scope.n_writers
    zeros = (0,) * n
    return State(
        disk_epoch=1,
        stamps=((1, 0, zeros),),     # the run's first stamp, cycle 0
        alive=(True,) * n,
        sess_epoch=(1,) * n,
        coords=(
            Coord("active", 1, zeros, zeros, zeros),
            Coord("standby", 0, zeros, zeros, zeros),
        ),
        crashes=0,
        takeovers=0,
    )


def _last_stamp_wm(st: State, j: int) -> int:
    return st.stamps[-1][2][j]


def _holder(scope: Scope, j: int) -> int:
    return (j + 1) % scope.n_writers


# ---------------------------------------------------------------------
# transition relation: yields (label, successor | Violation)

def successors(st: State, scope: Scope,
               mutant: Optional[str]) -> Iterator[Tuple[str, object]]:
    n = scope.n_writers
    for ci, c in enumerate(st.coords):
        if c.status != "active":
            continue
        # -- send one more save frame to a live writer ----------------
        for j in range(n):
            if st.alive[j] and c.sent[j] < scope.saves[ci]:
                nc = c._replace(sent=_tset(c.sent, j, c.sent[j] + 1))
                yield (f"C{ci}: send save#{c.sent[j] + 1} -> w{j}",
                       st._replace(coords=_tset(st.coords, ci, nc)))
        # -- writer applies / stale-rejects the oldest in-flight frame
        for j in range(n):
            if not st.alive[j] or c.applied[j] >= c.sent[j]:
                continue
            if c.epoch >= st.sess_epoch[j]:
                nc = c._replace(
                    applied=_tset(c.applied, j, c.applied[j] + 1))
                yield (f"w{j}: apply+ack save#{c.applied[j] + 1} "
                       f"from C{ci}",
                       st._replace(coords=_tset(st.coords, ci, nc)))
            else:
                # epoch fence: ("stale", ...) latches the endpoint
                nc = c._replace(status="aborted")
                yield (f"w{j}: stale-reject C{ci} (cmd epoch {c.epoch} "
                       f"< session epoch {st.sess_epoch[j]})",
                       st._replace(coords=_tset(st.coords, ci, nc)))
        # -- parity: the holder folds one applied frame ----------------
        for j in range(n):
            h = _holder(scope, j)
            if st.alive[h] and c.folded[j] < c.applied[j]:
                nc = c._replace(
                    folded=_tset(c.folded, j, c.folded[j] + 1))
                yield (f"w{h}: fold parity of w{j} save#"
                       f"{c.folded[j] + 1} (C{ci} stream)",
                       st._replace(coords=_tset(st.coords, ci, nc)))
        # -- DRAIN + STAMP --------------------------------------------
        yield from _stamp(st, scope, ci, c, mutant)
    # -- writer SIGKILL -----------------------------------------------
    if st.crashes < scope.max_crashes:
        for j in range(n):
            if st.alive[j]:
                yield (f"w{j}: SIGKILL",
                       st._replace(alive=_tset(st.alive, j, False),
                                   crashes=st.crashes + 1))
    # -- standby takeover ---------------------------------------------
    if st.takeovers < scope.max_takeovers:
        yield from _takeover(st, mutant)


def _drained(c: Coord, st: State, scope: Scope, ci: int,
             mutant: Optional[str]) -> Optional[Tuple[int, ...]]:
    """Per-writer stamp watermarks once the DRAIN barrier is complete —
    None while saves are still in flight.  Dead writers roll back to
    the previous stamp (their cycle did not complete)."""
    wms = []
    for j in range(len(st.alive)):
        if not st.alive[j]:
            wms.append(_last_stamp_wm(st, j))
            continue
        if c.sent[j] < scope.saves[ci]:
            return None                  # cycle's saves not all sent yet
        if mutant == "stamp-unacked":
            wms.append(c.sent[j])        # BUG: not waiting for the ack
        else:
            if c.applied[j] < c.sent[j]:
                return None              # drained reply not back yet
            wms.append(c.applied[j])
    return tuple(wms)


def _stamp(st: State, scope: Scope, ci: int, c: Coord,
           mutant: Optional[str]) -> Iterator[Tuple[str, object]]:
    wms = _drained(c, st, scope, ci, mutant)
    if wms is None:
        return
    label = f"C{ci}: STAMP cycle wm={wms} under epoch {c.epoch}"
    # the pre-STAMP COORDINATOR re-read: a successor's claim aborts us
    if mutant != "skip-stamp-reread" and st.disk_epoch != c.epoch:
        nc = c._replace(status="stale")
        yield (f"C{ci}: pre-STAMP re-read sees epoch {st.disk_epoch} "
               f"!= {c.epoch}: abort (StaleCoordinatorError)",
               st._replace(coords=_tset(st.coords, ci, nc)))
        return
    # I1: a stamp never references an unacked event
    for j in range(len(wms)):
        if st.alive[j] and wms[j] > c.applied[j]:
            yield (label, Violation(
                "I1", f"stamp watermark {wms[j]} for w{j} exceeds its "
                      f"durable applied count {c.applied[j]}: the "
                      f"stamped cycle references an unacked event"))
            return
    # I3: one stamper per epoch; stamp epoch == on-disk epoch
    if st.disk_epoch != c.epoch:
        yield (label, Violation(
            "I3", f"C{ci} stamps under epoch {c.epoch} while the disk "
                  f"COORDINATOR epoch is {st.disk_epoch}: a superseded "
                  f"primary stamped after a takeover"))
        return
    for (e, owner, _) in st.stamps:
        if e == c.epoch and owner != ci:
            yield (label, Violation(
                "I3", f"epoch {c.epoch} has two stampers "
                      f"(C{owner} and C{ci})"))
            return
    nc = c._replace(status="done")
    yield (label, st._replace(
        stamps=st.stamps + ((c.epoch, ci, wms),),
        coords=_tset(st.coords, ci, nc)))


def _takeover(st: State,
              mutant: Optional[str]) -> Iterator[Tuple[str, object]]:
    ci = next((i for i, c in enumerate(st.coords)
               if c.status == "standby"), None)
    if ci is None:
        return
    new_epoch = (st.disk_epoch if mutant == "reuse-epoch"
                 else st.disk_epoch + 1)
    label = (f"C{ci}: takeover — claim epoch {new_epoch}, write "
             f"COORDINATOR, re-point live sessions")
    # I2: COORDINATOR epoch writes strictly increase
    if new_epoch <= st.disk_epoch:
        yield (label, Violation(
            "I2", f"takeover writes COORDINATOR epoch {new_epoch} over "
                  f"{st.disk_epoch}: epochs must strictly increase or "
                  f"the fence cannot order coordinators"))
        return
    nc = st.coords[ci]._replace(status="active", epoch=new_epoch)
    sess = tuple(new_epoch if st.alive[j] else st.sess_epoch[j]
                 for j in range(len(st.alive)))
    yield (label, st._replace(
        disk_epoch=new_epoch, sess_epoch=sess,
        coords=_tset(st.coords, ci, nc),
        takeovers=st.takeovers + 1))


def _check_recovery(st: State, scope: Scope,
                    mutant: Optional[str]) -> Optional[Violation]:
    """I4/I5, evaluated on every state with a dead writer: what would
    ``reconstruct_shard`` / ``load_latest`` recover right now?"""
    for j in range(scope.n_writers):
        if st.alive[j]:
            continue
        h = _holder(scope, j)
        streams = [c for c in st.coords if c.status != "standby"]
        fresh = st.alive[h] and all(c.folded[j] == c.applied[j]
                                    for c in streams)
        adopt = st.alive[h] if mutant == "adopt-stale-stripe" else fresh
        if adopt:
            # I4: an adopted stripe must equal the victim's history
            for c in streams:
                if c.folded[j] != c.applied[j]:
                    return Violation(
                        "I4", f"reconstruction of w{j} adopts a stripe "
                              f"holding {c.folded[j]} of {c.applied[j]} "
                              f"applied saves: stale stripe adopted")
        else:
            # I5: fall back exactly to the last stamped cycle
            wm = _last_stamp_wm(st, j)
            ceiling = max([c.applied[j] for c in streams] or [0])
            if wm > ceiling:
                return Violation(
                    "I5", f"recovery of w{j} lands on watermark {wm} "
                          f"beyond its durable history {ceiling}: not "
                          f"a stamped-cycle state")
    return None


# ---------------------------------------------------------------------
# exhaustive exploration


class Result(NamedTuple):
    states: int
    transitions: int
    violation: Optional[Violation]
    trace: List[str]          # action labels root -> violation


def explore(scope: Scope = FULL, mutant: Optional[str] = None,
            max_states: int = 2_000_000) -> Result:
    if mutant is not None and mutant not in MUTANTS:
        raise ValueError(f"unknown mutant {mutant!r} "
                         f"(known: {', '.join(sorted(MUTANTS))})")
    root = initial_state(scope)
    parent: Dict[State, Optional[Tuple[State, str]]] = {root: None}
    queue = deque([root])
    transitions = 0

    def trace_to(st: State, final_label: Optional[str]) -> List[str]:
        labels: List[str] = []
        cur: Optional[State] = st
        while parent[cur] is not None:
            prev, label = parent[cur]
            labels.append(label)
            cur = prev
        labels.reverse()
        if final_label is not None:
            labels.append(final_label)
        return labels

    while queue:
        st = queue.popleft()
        bad = _check_recovery(st, scope, mutant)
        if bad is not None:
            return Result(len(parent), transitions, bad,
                          trace_to(st, f"<< {bad.invariant} violated"
                                       f" in this state >>"))
        for label, nxt in successors(st, scope, mutant):
            transitions += 1
            if isinstance(nxt, Violation):
                return Result(len(parent), transitions, nxt,
                              trace_to(st, label))
            if nxt not in parent:
                if len(parent) >= max_states:
                    raise RuntimeError(
                        f"state space exceeds {max_states} states — "
                        f"shrink the scope")
                parent[nxt] = (st, label)
                queue.append(nxt)
    return Result(len(parent), transitions, None, [])


def _print_trace(res: Result) -> None:
    print(f"  counterexample ({len(res.trace)} steps):")
    for i, label in enumerate(res.trace, 1):
        print(f"    {i:2d}. {label}")
    print(f"  violation [{res.violation.invariant}]: "
          f"{res.violation.message}")


def run_check(fast: bool = False, mutant: Optional[str] = None,
              quiet: bool = False) -> int:
    """Baseline must be violation-free; every mutant must be caught.
    Returns a process exit code."""
    scope = FAST if fast else FULL
    mutants = [mutant] if mutant else sorted(MUTANTS)
    say = (lambda *a: None) if quiet else print
    say(f"scope: {scope.n_writers} writers, saves/cycle {scope.saves}, "
        f"<= {scope.max_crashes} writer crash(es), "
        f"<= {scope.max_takeovers} takeover(s)")
    res = explore(scope)
    if res.violation is not None:
        say("BASELINE VIOLATION — the protocol model itself is broken:")
        _print_trace(res)
        return 1
    say(f"baseline: {res.states} states / {res.transitions} "
        f"transitions exhausted, all invariants hold")
    failed = []
    for name in mutants:
        res = explore(scope, mutant=name)
        if res.violation is None:
            failed.append(name)
            say(f"mutant {name}: NOT CAUGHT "
                f"({res.states} states) — checker has a blind spot")
        else:
            say(f"mutant {name}: caught "
                f"[{res.violation.invariant}] after {res.states} states")
            if not quiet:
                _print_trace(res)
    return 1 if failed else 0
