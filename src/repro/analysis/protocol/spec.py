"""Machine-readable wire spec for the writer-fleet protocol.

One declaration per frame kind: name, direction, arity range, field
names and coarse field types, which slot carries the coordinator epoch,
and the connection states in which the frame is legal.  The connection
state machine (socket transport; pipe/inproc skip the handshake states):

    start ──("hello")──> negotiated ──("mx" envelopes)──> (muxed)
      │                     │
      └──────┬──────────────┘
             ├─("spawn")────────────────────> serving
             └─("attach")──> attaching ──("reconcile"/"rebuild")─> serving
                                  │
                                  └─("no-writer" -> "spawn")─────> serving
    serving ──("close" / EOF / protocol violation)──> closed

In ``serving`` the per-shard command set is live: full/rows/trainer
saves, parity stripes, drain fences, image/export/reshard, ping, close.
Every consumer of the protocol derives from this module and nothing
else:

* ``repro.analysis.rules.protocol`` — AST conformance: every frame
  construction and dispatch site on both sides is checked against
  ``FRAMES`` (kind known, arity in range, epoch threaded through the
  declared slot, direction matches the side constructing it).
* ``repro.core.transport`` / ``repro.launch.shard_server`` — runtime:
  ``MAX_FRAME_BYTES`` caps hostile length prefixes, and
  ``validate_frame`` rejects malformed inbound frames in the serve loop
  *before* they can index-error a session thread.
* ``docs/recovery.md`` — the wire table between the
  ``<!-- wire-spec:begin -->`` markers is ``render_wire_table()``
  verbatim; the ``wire-doc-drift`` rule fails analysis on disagreement.
* ``repro.analysis.protocol.model`` / ``.fuzz`` — the model checker's
  alphabet and the fuzzer's grammar.

Stdlib only: this module is imported by the analysis CI job (no numpy)
and by ``repro.core.transport`` (workers never import jax).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

# Hard ceiling on a single wire frame (length prefix, compressed or
# raw, and post-inflate size).  A hostile 8-byte prefix can claim up to
# 2**63-1 bytes; without this cap the receiver would try to buffer (or
# zlib-inflate) the claim before noticing the stream is garbage.  Large
# enough for any real frame (a full-fleet snapshot shard is << 1 GiB),
# small enough that an allocation bomb dies as a clean ProtocolError.
MAX_FRAME_BYTES = 1 << 31

# Connection states (socket transport; the pipe/inproc transports are
# born in "serving").
STATES = (
    "start",        # raw connection, nothing sent
    "negotiated",   # hello/hello-ok done (codec/mux/shm agreed)
    "attaching",    # attach sent, takeover handshake in flight
    "serving",      # per-shard session live (spawned or reconciled)
    "closed",       # close frame, EOF, or poisoned channel
)

C2W = "c2w"   # coordinator -> worker
W2C = "w2c"   # worker -> coordinator
BOTH = "both"  # connection-level envelope, rides both directions

# Coarse field types for runtime validation.  "any" is unchecked;
# "int"/"str" are enforced by validate_frame (cheap and unambiguous —
# payload buffers, trees, and array lists stay "any").
_T = {"int", "str", "any"}


@dataclass(frozen=True)
class FrameSpec:
    """One wire-frame kind.  ``fields``/``types`` cover ``max_arity``
    slots including slot 0 (the kind tag itself); frames between
    ``min_arity`` and ``max_arity`` simply omit the tail."""

    kind: str
    direction: str                    # C2W | W2C | BOTH
    min_arity: int
    max_arity: int
    fields: Tuple[str, ...]
    types: Tuple[str, ...]
    states: Tuple[str, ...]           # states in which the frame is legal
    epoch_slot: Optional[int] = None  # slot carrying the coordinator epoch
    section: str = "session"          # wire-table grouping
    doc: str = ""

    def __post_init__(self):
        assert self.direction in (C2W, W2C, BOTH), self.kind
        assert 1 <= self.min_arity <= self.max_arity, self.kind
        assert len(self.fields) == self.max_arity, self.kind
        assert len(self.types) == self.max_arity, self.kind
        assert all(t in _T for t in self.types), self.kind
        assert all(s in STATES for s in self.states), self.kind
        if self.epoch_slot is not None:
            assert 0 < self.epoch_slot < self.max_arity, self.kind


def _f(kind, direction, fields, types, states, *, min_arity=None,
       epoch_slot=None, section="session", doc=""):
    fields = tuple(fields)
    return FrameSpec(
        kind=kind, direction=direction,
        min_arity=len(fields) if min_arity is None else min_arity,
        max_arity=len(fields), fields=fields, types=tuple(types),
        states=tuple(states), epoch_slot=epoch_slot, section=section,
        doc=doc)


_SERVING = ("serving",)
_PRE = ("start", "negotiated")

# The spec proper.  Keyed by (kind, direction) because one kind —
# "image" — is both the c2w request and the w2c reply with different
# shapes.  Order here is the wire-table order.
_DECLS = [
    # -- connection negotiation + envelopes ---------------------------
    _f("hello", C2W, ("kind", "epoch", "opts"), ("str", "int", "any"),
       ("start",), epoch_slot=1, section="envelope",
       doc="negotiate codec/mux/shm before any per-shard traffic"),
    _f("hello-ok", W2C, ("kind", "opts"), ("str", "any"),
       ("start",), section="envelope",
       doc="server's accepted options (e.g. shm probe verdict)"),
    _f("mx", BOTH, ("kind", "shard", "inner"), ("str", "int", "any"),
       ("negotiated", "attaching", "serving"), section="envelope",
       doc="mux envelope: every frame of a multiplexed connection"),
    # -- session establishment ----------------------------------------
    _f("spawn", C2W,
       ("kind", "shard", "table_sizes", "n_shards", "directory",
        "seed_t", "seed_a", "seed_tr", "fsync", "epoch", "boundaries"),
       ("str", "int", "any", "int", "any", "any", "any", "any", "any",
        "int", "any"),     # directory is None until the first save
       _PRE + ("attaching",), min_arity=9, epoch_slot=9,
       section="handshake",
       doc="create the shard session (socket only; epoch+boundaries "
           "tails are optional for legacy senders)"),
    _f("attach", C2W, ("kind", "epoch", "shard"), ("str", "int", "int"),
       _PRE, epoch_slot=1, section="handshake",
       doc="takeover: adopt a still-running writer session"),
    _f("attach-ok", W2C, ("kind", "watermark", "err"),
       ("str", "any", "any"), ("attaching",), section="handshake"),
    _f("no-writer", W2C, ("kind",), ("str",), ("attaching",),
       section="handshake",
       doc="no parked session: coordinator falls back to spawn"),
    _f("reconcile", C2W,
       ("kind", "epoch", "directory", "watermark", "seed_t", "seed_a",
        "seed_tr"),
       ("str", "int", "str", "any", "any", "any", "any"),
       ("attaching",), epoch_slot=1, section="handshake",
       doc="adopt the session at the stamped watermark (seeds only if "
           "the image must be rebuilt)"),
    _f("reconciled", W2C, ("kind", "watermark"), ("str", "any"),
       ("attaching",), section="handshake"),
    _f("rebuild", C2W,
       ("kind", "epoch", "directory", "watermark", "seed_t", "seed_a",
        "seed_tr", "plan"),
       ("str", "int", "str", "any", "any", "any", "any", "any"),
       ("attaching", "serving"), epoch_slot=1, section="handshake",
       doc="writer-local replay of a shard chain the coordinator "
           "cannot read"),
    _f("rebuilt", W2C, ("kind", "watermark"), ("str", "any"),
       ("attaching", "serving"), section="handshake"),
    # -- save traffic --------------------------------------------------
    _f("full", C2W, ("kind", "epoch", "seq", "step", "payload"),
       ("str", "int", "int", "int", "any"), _SERVING, epoch_slot=1,
       section="save", doc="full-image save event"),
    _f("rows", C2W,
       ("kind", "epoch", "seq", "step", "table", "rows", "values",
        "accs"),
       ("str", "int", "int", "int", "int", "any", "any", "any"),
       _SERVING, epoch_slot=1, section="save",
       doc="partial (delta) save of one table's row slice"),
    _f("trainer", C2W, ("kind", "epoch", "seq", "step", "tree"),
       ("str", "int", "int", "int", "any"), _SERVING, epoch_slot=1,
       section="save", doc="trainer-state replica (shard 0)"),
    _f("ack", W2C, ("kind", "seq", "event"), ("str", "int", "any"),
       _SERVING, section="save",
       doc="event durable on the writer's disk"),
    _f("error", W2C, ("kind", "seq", "err"), ("str", "int", "any"),
       _SERVING, section="save",
       doc="apply failed; shard poisoned (seq -1: protocol violation)"),
    # -- fence / liveness / image -------------------------------------
    _f("drain", C2W, ("kind", "epoch", "token"), ("str", "int", "any"),
       _SERVING, epoch_slot=1, section="fence",
       doc="DRAIN barrier: reply once everything queued is durable"),
    _f("drained", W2C, ("kind", "token", "watermark", "err"),
       ("str", "any", "any", "any"), _SERVING, section="fence"),
    _f("image", C2W, ("kind", "epoch"), ("str", "int"), _SERVING,
       epoch_slot=1, section="fence",
       doc="request the writer's current in-memory image"),
    _f("image", W2C, ("kind", "tables", "accs", "trainer"),
       ("str", "any", "any", "any"), _SERVING, section="fence"),
    _f("ping", C2W, ("kind", "epoch", "token"), ("str", "int", "any"),
       _SERVING, epoch_slot=1, section="fence",
       doc="heartbeat liveness probe"),
    _f("pong", W2C, ("kind", "token"), ("str", "any"), _SERVING,
       section="fence"),
    _f("stale", W2C, ("kind", "cmd_kind", "cmd_epoch", "epoch"),
       ("str", "str", "any", "int"), ("attaching", "serving"),
       epoch_slot=3, section="fence",
       doc="epoch fence: command older than the session's epoch "
           "(or a superseded generation) — never executed"),
    _f("close", C2W, ("kind", "epoch"), ("str", "int"), _SERVING,
       epoch_slot=1, section="fence",
       doc="park the session (socket) / stop the worker (pipe)"),
    # -- parity stripes (soft state) ----------------------------------
    _f("parity", C2W,
       ("kind", "epoch", "seq", "step", "op", "group", "a6", "a7",
        "a8", "a9"),
       ("str", "int", "int", "int", "str", "int", "any", "any", "any",
        "any"),
       _SERVING, min_arity=8, epoch_slot=1, section="parity",
       doc='op "full": (tables, accs) stripe seed, arity 8; '
           'op "delta": (table, stripe_rows, xvals, xaccs), arity 10'),
    _f("parity-ok", W2C, ("kind", "seq", "nbytes"),
       ("str", "int", "any"), _SERVING, section="parity"),
    _f("parity-get", C2W, ("kind", "epoch", "group"),
       ("str", "int", "int"), _SERVING, epoch_slot=1, section="parity",
       doc="fetch the running stripe for reconstruction"),
    _f("parity-out", W2C, ("kind", "group", "tables", "accs"),
       ("str", "int", "any", "any"), _SERVING, section="parity"),
    # -- elastic resharding -------------------------------------------
    _f("export", C2W, ("kind", "epoch", "ranges"),
       ("str", "int", "any"), _SERVING, epoch_slot=1,
       section="elastic",
       doc="stream out row ranges leaving this shard"),
    _f("rows-out", W2C, ("kind", "shard", "tables", "accs"),
       ("str", "int", "any", "any"), _SERVING, section="elastic"),
    _f("reshard", C2W,
       ("kind", "epoch", "table_sizes", "n_shards", "boundaries",
        "directory", "seed_t", "seed_a", "seed_tr"),
       ("str", "int", "any", "int", "any", "str", "any", "any", "any"),
       _SERVING, epoch_slot=1, section="elastic",
       doc="adopt a new shard layout in place"),
    _f("resharded", W2C, ("kind", "shard", "watermark"),
       ("str", "int", "any"), _SERVING, section="elastic"),
]

# (kind, direction) -> FrameSpec.  Kinds are unique per direction.
FRAMES = {}
for _d in _DECLS:
    _key = (_d.kind, _d.direction)
    assert _key not in FRAMES, _key
    FRAMES[_key] = _d
del _d, _key

KINDS = frozenset(k for k, _ in FRAMES)

_SECTIONS = (
    ("envelope", "Connection negotiation + envelopes (socket only)"),
    ("handshake", "Session establishment / coordinator failover"),
    ("save", "Save traffic"),
    ("fence", "Fence, liveness, image"),
    ("parity", "XOR parity stripes (soft state)"),
    ("elastic", "Elastic resharding"),
)


def frames_for(kind: str, direction: Optional[str] = None):
    """All FrameSpec entries for ``kind`` (one or, for "image", two);
    with ``direction``, only entries legal for that direction (BOTH
    matches either)."""
    out = [f for (k, _), f in sorted(FRAMES.items()) if k == kind]
    if direction is not None:
        out = [f for f in out
               if f.direction == direction or f.direction == BOTH]
    return out


def violation(msg: object, direction: str = C2W,
              state: Optional[str] = None) -> Optional[str]:
    """Why ``msg`` is not a well-formed frame for ``direction`` — or
    None if it conforms.  Structural checks only (tuple-ness, kind
    known, arity in range, int/str slots): cheap enough for the serve
    loop's hot path, strict enough that a conforming frame can never
    index-error a handler.  With ``state``, the frame must also be
    legal in that connection state (e.g. a 'hello' arriving on a
    session already in 'serving' is a violation)."""
    if not isinstance(msg, tuple):
        return f"frame is {type(msg).__name__}, not tuple"
    if not msg:
        return "empty frame"
    kind = msg[0]
    if not isinstance(kind, str):
        return f"frame kind is {type(kind).__name__}, not str"
    specs = frames_for(kind, direction)
    if not specs:
        if frames_for(kind):
            return f"frame kind {kind!r} is not legal in direction " \
                   f"{direction!r}"
        return f"unknown frame kind {kind!r}"
    if state is not None:
        specs = [f for f in specs if state in f.states]
        if not specs:
            return f"frame kind {kind!r} is not legal in connection " \
                   f"state {state!r}"
    why = None
    for spec in specs:
        why = _violation_against(msg, spec)
        if why is None:
            return None
    return why


def _violation_against(msg: tuple, spec: FrameSpec) -> Optional[str]:
    n = len(msg)
    if not spec.min_arity <= n <= spec.max_arity:
        want = (str(spec.min_arity) if spec.min_arity == spec.max_arity
                else f"{spec.min_arity}..{spec.max_arity}")
        return f"{spec.kind!r} frame has arity {n}, spec says {want}"
    for i in range(1, n):
        t, val = spec.types[i], msg[i]
        if t == "int" and not (isinstance(val, int)
                               and not isinstance(val, bool)):
            return f"{spec.kind!r} slot {i} ({spec.fields[i]}) is " \
                   f"{type(val).__name__}, spec says int"
        if t == "str" and not isinstance(val, str):
            return f"{spec.kind!r} slot {i} ({spec.fields[i]}) is " \
                   f"{type(val).__name__}, spec says str"
    if spec.kind == "parity":
        op = msg[4]
        want = {"full": 8, "delta": 10}.get(op)
        if want is None:
            return f"'parity' op {op!r} is neither 'full' nor 'delta'"
        if n != want:
            return f"'parity' op {op!r} has arity {n}, spec says {want}"
    return None


def validate_frame(msg: object, direction: str = C2W) -> bool:
    """True iff ``msg`` is a well-formed frame for ``direction``."""
    return violation(msg, direction) is None


# ---------------------------------------------------------------------
# Wire-table rendering: docs/recovery.md embeds this verbatim between
# "<!-- wire-spec:begin -->" / "<!-- wire-spec:end -->" markers; the
# wire-doc-drift rule fails analysis when they disagree.  Regenerate:
#   PYTHONPATH=src python -m repro.analysis.protocol --write-table

WIRE_TABLE_BEGIN = "<!-- wire-spec:begin -->"
WIRE_TABLE_END = "<!-- wire-spec:end -->"

_DIR_LABEL = {C2W: "coord -> worker", W2C: "worker -> coord",
              BOTH: "both"}


def _sig(spec: FrameSpec) -> str:
    parts = [repr(spec.kind)]
    parts += list(spec.fields[1:spec.min_arity])
    for name in spec.fields[spec.min_arity:]:
        parts.append(f"[{name}]")
    return "(" + ", ".join(parts) + ")"


def render_wire_table() -> str:
    """Deterministic markdown wire table, derived from FRAMES only."""
    lines = [
        "Generated from `repro.analysis.protocol.spec` — edit the spec,",
        "not this table (`python -m repro.analysis.protocol"
        " --write-table`).",
        "",
        "| frame | direction | arity | epoch slot | legal states |",
        "|-------|-----------|-------|------------|--------------|",
    ]
    for section, title in _SECTIONS:
        specs = [f for f in _DECLS if f.section == section]
        if not specs:
            continue
        lines.append(f"| **{title}** | | | | |")
        for spec in specs:
            arity = (str(spec.min_arity)
                     if spec.min_arity == spec.max_arity
                     else f"{spec.min_arity}..{spec.max_arity}")
            ep = "—" if spec.epoch_slot is None else str(spec.epoch_slot)
            states = ", ".join(spec.states)
            lines.append(
                f"| `{_sig(spec)}` | {_DIR_LABEL[spec.direction]} | "
                f"{arity} | {ep} | {states} |")
    lines.append("")
    lines.append(f"Max frame size (prefix, compressed, and inflated): "
                 f"`MAX_FRAME_BYTES = {MAX_FRAME_BYTES}` bytes; "
                 f"oversized or malformed frames raise `ProtocolError` "
                 f"and sever the channel.")
    return "\n".join(lines) + "\n"
