"""Spec-derived protocol fuzzer: poison-not-corrupt, demonstrated.

Boots one real ``shard_server`` (in-process thread), runs a real
two-shard socket fleet against it through one save + fence (so the run
directory holds a stamped manifest), severs the coordinator (sessions
park, exactly as after a coordinator SIGKILL), then fires hundreds of
hostile frames derived *from the wire spec* at the live server:

* wrong-state frames (session commands as connection openers, handshake
  frames mid-session),
* arity mutations (one slot short / one slot extra),
* type confusion in int/str slots,
* stale-epoch handshakes (attach with an epoch the session already
  outran),
* truncated frame bodies and lying length prefixes,
* length-prefix bombs and zlib decompression bombs,
* malformed mux envelopes and inner frames,
* raw random bytes.

The oracle is the CPR durability contract: whatever the fuzzer does,
the stamped run directory must stay byte-identical, ``load_latest``
must return the stamped image, and the server must still answer a
legitimate handshake afterwards.  Sessions are allowed (expected!) to
poison — they must never corrupt.

Needs numpy (it runs a real fleet): deliberately NOT imported by
``repro.analysis.protocol`` itself, so the stdlib-only analysis path
stays importable without it.

Run: ``PYTHONPATH=src python -m repro.analysis.protocol --fuzz``
"""
from __future__ import annotations

import hashlib
import os
import random
import socket
import struct
import tempfile
import threading
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

from repro.analysis.protocol import spec as wire
from repro.core.checkpoint import EmbShardSpec, resolve_run_dir
from repro.core.sharded_checkpoint import ShardedCheckpointWriter
from repro.core.transport import SockChannel, pack_msg
from repro.launch import shard_server

SIZES = (4_000, 1_000)
DIM = 8
N_SHARDS = 2

# session-creating kinds are only ever generated with junk directories
# and out-of-range shard ids: a fuzz frame must never be able to name
# the oracle's run directory or adopt the real shards' sessions
_JUNK_DIR = "/nonexistent/cpr-fuzz-junk"
_JUNK_SHARD_BASE = 100


def _start_server() -> Tuple[str, int]:
    ready = threading.Event()
    box: Dict[str, Tuple[str, int]] = {}

    def ready_cb(h, p):
        box["hp"] = (h, p)
        ready.set()

    t = threading.Thread(target=shard_server.serve,
                         args=("127.0.0.1", 0, ready_cb),
                         name="cpr-fuzz-shard-server", daemon=True)
    t.start()
    if not ready.wait(10.0):
        raise RuntimeError("shard server failed to bind")
    return box["hp"]


def _snapshot_dir(root: str) -> Dict[str, str]:
    """relpath -> sha256 of every file under the run directory tree."""
    out: Dict[str, str] = {}
    for dirpath, _dirs, files in os.walk(root):
        for name in files:
            full = os.path.join(dirpath, name)
            with open(full, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            out[os.path.relpath(full, root)] = digest
    return out


# ---------------------------------------------------------------------
# attack grammar (derived from the spec, never hand-listed)

def _junk_value(rng: random.Random):
    return rng.choice([
        None, -1, 2**40, "junk", b"\x00\xff" * 3, 3.14, True,
        ("nested",), [1, 2], {"k": "v"},
    ])


def _fill(fspec: wire.FrameSpec, rng: random.Random, arity: int) -> tuple:
    """A frame of ``arity`` slots for ``fspec`` whose typed slots are
    *well*-typed (so only the mutation under test is hostile)."""
    out = [fspec.kind]
    for i in range(1, arity):
        t = fspec.types[i] if i < len(fspec.types) else "any"
        if t == "int":
            out.append(rng.randrange(0, 1000))
        elif t == "str":
            out.append("full" if fspec.kind == "parity" else "x")
        else:
            out.append(_junk_value(rng))
    if fspec.kind == "spawn":
        if arity > 4:
            out[4] = _JUNK_DIR                  # never the oracle's dir
        if arity > 1:
            out[1] = _JUNK_SHARD_BASE + rng.randrange(50)
    if fspec.kind in ("reconcile", "rebuild") and arity > 2:
        out[2] = _JUNK_DIR
    if fspec.kind == "attach" and arity > 2:
        out[2] = _JUNK_SHARD_BASE + rng.randrange(50)
    return tuple(out)


def _c2w_specs():
    return [f for f in wire.FRAMES.values()
            if f.direction in (wire.C2W, wire.BOTH)]


def _attack_wrong_state(rng: random.Random) -> tuple:
    """A structurally valid frame that is illegal as a connection
    opener (serving-only command) or mid-session (handshake kind)."""
    serving_only = [f for f in _c2w_specs() if "start" not in f.states]
    f = rng.choice(serving_only)
    return _fill(f, rng, f.min_arity)


def _attack_arity(rng: random.Random) -> tuple:
    f = rng.choice(_c2w_specs())
    if rng.random() < 0.5 and f.min_arity > 1:
        return _fill(f, rng, f.min_arity - 1)
    return _fill(f, rng, f.max_arity) + (_junk_value(rng),)


def _attack_type_confusion(rng: random.Random) -> Optional[tuple]:
    typed = [f for f in _c2w_specs()
             if any(t in ("int", "str") for t in f.types[1:])]
    f = rng.choice(typed)
    msg = list(_fill(f, rng, f.min_arity))
    slots = [i for i in range(1, f.min_arity)
             if f.types[i] in ("int", "str")]
    i = rng.choice(slots)
    msg[i] = b"\xde\xad" if f.types[i] == "str" else "not-an-int"
    return tuple(msg)


def _attack_unknown_kind(rng: random.Random) -> tuple:
    kind = rng.choice(["flush", "sync", "xyzzy", "", "mx2", "ack"])
    return (kind,) + tuple(_junk_value(rng) for _ in range(rng.randrange(4)))


def _attack_not_a_tuple(rng: random.Random):
    return rng.choice([None, 42, "spawn", ["spawn", 1], {"kind": "ping"}, ()])


class _Conn:
    """One hostile TCP connection (bounded lifetime, errors swallowed:
    dying on a reset peer is the *server's* success, not ours)."""

    def __init__(self, addr, timeout=2.0):
        self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)

    def send_frame(self, msg):
        body = pack_msg(msg)
        self.sock.sendall(struct.pack(">Q", len(body)) + body)

    def send_raw(self, data: bytes):
        self.sock.sendall(data)

    def recv_frame(self):
        chan = SockChannel(self.sock)
        return chan.recv()

    def close(self):
        try:
            self.sock.close()
        except OSError:
            pass


def _hello(conn: _Conn, epoch=1, opts=None):
    conn.send_frame(("hello", epoch, opts or {}))
    return conn.recv_frame()


def run_fuzz(frames: int = 500, seed: int = 0,
             root: Optional[str] = None) -> Dict[str, object]:
    """Fire ``frames`` hostile frames at a live shard_server; assert
    the stamped run directory survives byte-identical and the loaded
    image matches the pre-attack oracle.  Returns a stats dict."""
    rng = random.Random(seed)
    addr = _start_server()
    if root is None:
        root = tempfile.mkdtemp(prefix="cpr-fuzz-")

    # -- oracle: one stamped save through the real fleet ---------------
    np_rng = np.random.default_rng(seed)
    tables = [np_rng.normal(size=(n, DIM)).astype(np.float32)
              for n in SIZES]
    accs = [np.zeros((n, DIM), np.float32) for n in SIZES]
    espec = EmbShardSpec(SIZES, N_SHARDS)
    fleet = ShardedCheckpointWriter(
        tables, accs, espec, directory=root, backend="socket",
        addresses=[addr] * N_SHARDS, delta_saves=False,
        drain_timeout=30.0)
    v1_t = [t + 1 for t in tables]
    v1_a = [a + 1 for a in accs]
    fleet.save_full(v1_t, v1_a, step=1)
    fleet.fence()                       # durable: CURRENT now points at v1
    live_epoch = fleet.epoch if isinstance(
        getattr(fleet, "epoch", None), int) else 1
    for p in fleet.procs:               # coordinator "dies": sessions park
        p.sever()

    run_dir = resolve_run_dir(root)
    assert run_dir is not None, "fence did not advance CURRENT"
    oracle_fs = _snapshot_dir(root)
    lt, la, _ = ShardedCheckpointWriter.load_latest(
        root, tables, accs, espec).restore_all()
    oracle_tables = [t.copy() for t in lt]
    oracle_accs = [a.copy() for a in la]

    # -- the attacks ---------------------------------------------------
    stats: Dict[str, int] = {}
    replies: Dict[str, int] = {}

    def note(category: str):
        stats[category] = stats.get(category, 0) + 1

    def fold_reply(conn: _Conn):
        try:
            msg = conn.recv_frame()
            kind = msg[0] if isinstance(msg, tuple) and msg else "?"
            replies[str(kind)] = replies.get(str(kind), 0) + 1
        except Exception:       # lint: allow[exception-hygiene] hostile
            # peer: EOF/reset/timeout are all acceptable server answers
            replies["<dead>"] = replies.get("<dead>", 0) + 1

    sent = 0
    while sent < frames:
        kind = rng.randrange(10)
        try:
            conn = _Conn(addr)
        except OSError:
            break               # server gone: the post-checks will fail
        try:
            if kind == 0:       # wrong-state opener
                conn.send_frame(_attack_wrong_state(rng))
                note("wrong-state")
            elif kind == 1:     # arity mutation as opener
                conn.send_frame(_attack_arity(rng))
                note("arity")
            elif kind == 2:     # type confusion as opener
                conn.send_frame(_attack_type_confusion(rng))
                note("type-confusion")
            elif kind == 3:     # unknown kind / non-tuple opener
                if rng.random() < 0.5:
                    conn.send_frame(_attack_unknown_kind(rng))
                else:
                    conn.send_frame(_attack_not_a_tuple(rng))
                note("unknown-kind")
            elif kind == 4:     # stale-epoch attach at a REAL shard
                conn.send_frame(("attach", 0, rng.randrange(N_SHARDS)))
                note("stale-epoch")
                fold_reply(conn)
            elif kind == 5:     # truncated body / lying prefix
                body = pack_msg(_fill(rng.choice(_c2w_specs()), rng, 3))
                if rng.random() < 0.5:
                    cut = rng.randrange(1, max(2, len(body)))
                    conn.send_raw(struct.pack(">Q", len(body))
                                  + body[:cut])
                else:
                    conn.send_raw(struct.pack(">Q", len(body) + 7)
                                  + body)
                note("truncated")
            elif kind == 6:     # length-prefix bomb
                conn.send_raw(struct.pack(">Q", 1 << 40) + b"\x00" * 64)
                note("prefix-bomb")
            elif kind == 7:     # zlib bomb behind the compressed bit
                blob = zlib.compress(b"\x00" * (1 << 22), 9)
                n = len(blob) | (1 << 63)
                conn.send_raw(struct.pack(">Q", n) + blob)
                note("zlib-bomb")
            elif kind == 8:     # mux: hostile envelopes + inner frames
                try:
                    _hello(conn, epoch=live_epoch,
                           opts={"mux": True})
                except Exception:   # lint: allow[exception-hygiene]
                    # handshake refused is a pass, not a failure
                    note("mux-garbage")
                    continue
                choice = rng.randrange(4)
                if choice == 0:
                    conn.send_frame(("mx",))                # short
                elif choice == 1:
                    conn.send_frame(("mx", "shard?", 1))    # bad shard
                elif choice == 2:
                    conn.send_frame(("mx", _JUNK_SHARD_BASE,
                                     _attack_not_a_tuple(rng)))
                else:
                    conn.send_frame(("not-mx", 1, 2))
                note("mux-garbage")
            else:               # raw random bytes
                conn.send_raw(rng.randbytes(rng.randrange(1, 64)))
                note("raw-bytes")
            sent += 1
        except OSError:
            sent += 1           # peer reset us mid-attack: acceptable
        finally:
            conn.close()

    # -- the oracle holds ----------------------------------------------
    after_fs = _snapshot_dir(root)
    assert after_fs == oracle_fs, (
        "fuzzing mutated the stamped run directory: "
        f"{sorted(set(after_fs.items()) ^ set(oracle_fs.items()))[:4]}")

    lt, la, _ = ShardedCheckpointWriter.load_latest(
        root, tables, accs, espec).restore_all()
    for got, want in zip(lt, oracle_tables):
        assert np.array_equal(got, want), "loaded table drifted"
    for got, want in zip(la, oracle_accs):
        assert np.array_equal(got, want), "loaded accumulator drifted"

    # server still answers a legitimate handshake
    conn = _Conn(addr)
    try:
        reply = _hello(conn, epoch=live_epoch + 1)
        assert isinstance(reply, tuple) and reply[0] == "hello-ok", (
            f"server no longer speaks the protocol: {reply!r}")
    finally:
        conn.close()

    return {
        "frames": sent,
        "categories": dict(sorted(stats.items())),
        "replies": dict(sorted(replies.items())),
        "disk_files": len(oracle_fs),
        "ok": True,
    }
