"""CLI for the protocol spec tooling.

    python -m repro.analysis.protocol --check [--fast] [--mutant NAME]
        exhaustively model-check the DRAIN/STAMP/takeover protocol:
        baseline must satisfy every stamp-safety invariant, every
        seeded mutant must be caught with a counterexample trace.
    python -m repro.analysis.protocol --table
        print the spec-derived wire table (what docs/recovery.md must
        embed between the wire-spec markers).
    python -m repro.analysis.protocol --write-table [--doc PATH]
        regenerate the wire table inside docs/recovery.md in place.
    python -m repro.analysis.protocol --fuzz [--frames N] [--seed S]
        spec-derived fuzz of a live shard_server (needs numpy;
        asserts poison-not-corrupt — see protocol/fuzz.py).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.protocol import spec
from repro.analysis.protocol.model import MUTANTS, run_check


def _default_doc() -> str:
    here = os.path.abspath(spec.__file__)
    for _ in range(5):
        here = os.path.dirname(here)
    return os.path.join(here, "docs", "recovery.md")


def write_table(doc_path: str) -> int:
    with open(doc_path, encoding="utf-8") as f:
        text = f.read()
    begin, end = spec.WIRE_TABLE_BEGIN, spec.WIRE_TABLE_END
    if begin not in text or end not in text:
        print(f"{doc_path}: missing {begin} / {end} markers",
              file=sys.stderr)
        return 2
    head, rest = text.split(begin, 1)
    _, tail = rest.split(end, 1)
    new = head + begin + "\n" + spec.render_wire_table() + end + tail
    if new == text:
        print(f"{doc_path}: wire table already up to date")
        return 0
    with open(doc_path, "w", encoding="utf-8") as f:
        f.write(new)
    print(f"{doc_path}: wire table regenerated from the spec")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.protocol",
        description="wire-spec tooling: model checker, table "
                    "generator, fuzzer")
    ap.add_argument("--check", action="store_true",
                    help="explicit-state model check (baseline + "
                         "seeded mutants)")
    ap.add_argument("--fast", action="store_true",
                    help="smaller save budget per cycle (CI-bounded "
                         "state space)")
    ap.add_argument("--mutant", choices=sorted(MUTANTS),
                    help="check only this seeded mutant")
    ap.add_argument("--table", action="store_true",
                    help="print the spec-derived wire table")
    ap.add_argument("--write-table", action="store_true",
                    help="regenerate the wire table in docs/recovery.md")
    ap.add_argument("--doc", default=None,
                    help="docs file for --write-table (default: the "
                         "repo's docs/recovery.md)")
    ap.add_argument("--fuzz", action="store_true",
                    help="fuzz a live shard_server (spawns one; "
                         "needs numpy)")
    ap.add_argument("--frames", type=int, default=500,
                    help="malformed frames to send with --fuzz")
    ap.add_argument("--seed", type=int, default=0,
                    help="fuzzer PRNG seed")
    args = ap.parse_args(argv)

    if args.table:
        sys.stdout.write(spec.render_wire_table())
        return 0
    if args.write_table:
        return write_table(args.doc or _default_doc())
    if args.fuzz:
        from repro.analysis.protocol.fuzz import run_fuzz
        stats = run_fuzz(frames=args.frames, seed=args.seed)
        print("fuzz stats:", stats)
        return 0
    if args.check:
        return run_check(fast=args.fast, mutant=args.mutant)
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
