"""Protocol-as-spec: the writer-fleet wire protocol as a first-class,
machine-verified artifact.

``spec``   — the machine-readable wire spec: every frame kind's name,
             arity, field names/types, epoch slot, direction, and the
             connection states in which it is legal.  Single source of
             truth: the AST conformance rule (``rules/protocol.py``),
             the runtime frame validator in the serve loop, the wire
             table in ``docs/recovery.md``, the model checker, and the
             fuzzer all derive from it.
``model``  — explicit-state model checker over an abstracted
             coordinator + N writers + disk, exhaustively enumerating
             small-scope interleavings of frames, SIGKILLs, and
             takeovers against the stamp-safety invariants
             (``python -m repro.analysis.protocol --check``).
``fuzz``   — spec-derived grammar fuzzer throwing malformed, truncated,
             wrong-state, and stale-epoch frames at a live
             ``shard_server`` and asserting poison-not-corrupt.

Everything imported here is pure stdlib (the ``analysis`` CI job runs
without numpy/jax); ``fuzz`` imports numpy and the live transport and is
therefore NOT imported at package level — import ``repro.analysis
.protocol.fuzz`` explicitly from tests or the CLI.
"""
from .spec import (FRAMES, KINDS, MAX_FRAME_BYTES, STATES, FrameSpec,
                   frames_for, render_wire_table, validate_frame)

__all__ = [
    "FRAMES",
    "KINDS",
    "MAX_FRAME_BYTES",
    "STATES",
    "FrameSpec",
    "frames_for",
    "render_wire_table",
    "validate_frame",
]
