"""CLI for the invariant linter: ``python -m repro.analysis``.

Exit status 0 iff every finding is suppressed (``# lint: allow[...]``)
or baselined; 1 otherwise.  ``--write-baseline`` grandfathers the
current unsuppressed findings so the rule can land before the cleanup.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import core
from repro.analysis import rules as _rules  # noqa: F401  (registers checkers)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="CPR invariant linter (see docs/analysis.md)")
    ap.add_argument("--rule", action="append", metavar="NAME",
                    help="run only this rule (repeatable; default: all)")
    ap.add_argument("--root", default=None,
                    help="tree to scan (default: the repro package)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="JSON findings baseline to subtract")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write current unsuppressed findings as a "
                         "baseline and exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in sorted(core.CHECKERS):
            print(f"{name}: {core.CHECKERS[name].description}")
        return 0

    try:
        report = core.run_analysis(root=args.root, rules=args.rule,
                                   baseline=args.baseline)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        core.write_baseline(report, args.write_baseline)
        print(f"wrote {len(report.baseline_records())} baseline record(s) "
              f"to {args.write_baseline}")
        return 0

    if args.as_json:
        json.dump(report.to_json(), sys.stdout, indent=2)
        print()
    else:
        for f in report.findings:
            print(f.render())
        bad = len(report.unsuppressed)
        print(f"{report.files_scanned} file(s), "
              f"{len(report.findings)} finding(s), "
              f"{bad} unsuppressed")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
