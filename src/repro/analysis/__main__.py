"""CLI for the invariant linter: ``python -m repro.analysis``.

Exit status 0 iff every finding is suppressed (``# lint: allow[...]``)
or baselined; 1 otherwise.  ``--write-baseline`` grandfathers the
current unsuppressed findings so the rule can land before the cleanup.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import core
from repro.analysis import rules as _rules  # noqa: F401  (registers checkers)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="CPR invariant linter (see docs/analysis.md)")
    ap.add_argument("--rule", action="append", metavar="NAME",
                    help="run only this rule (repeatable; default: all)")
    ap.add_argument("--root", default=None,
                    help="tree to scan (default: the repro package)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="JSON findings baseline to subtract")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write current unsuppressed findings as a "
                         "baseline and exit 0")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="shorthand for --format json")
    ap.add_argument("--format", choices=("text", "json", "sarif"),
                    default=None,
                    help="report format (default text; sarif is the "
                         "GitHub code-scanning dialect)")
    ap.add_argument("--output", default=None, metavar="PATH",
                    help="write the report to PATH instead of stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    fmt = args.format or ("json" if args.as_json else "text")

    if args.list_rules:
        for name in sorted(core.CHECKERS):
            print(f"{name}: {core.CHECKERS[name].description}")
        return 0

    try:
        report = core.run_analysis(root=args.root, rules=args.rule,
                                   baseline=args.baseline)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        core.write_baseline(report, args.write_baseline)
        print(f"wrote {len(report.baseline_records())} baseline record(s) "
              f"to {args.write_baseline}")
        return 0

    out = (open(args.output, "w", encoding="utf-8") if args.output
           else sys.stdout)
    try:
        if fmt == "json":
            json.dump(report.to_json(), out, indent=2)
            out.write("\n")
        elif fmt == "sarif":
            json.dump(report.to_sarif(), out, indent=2)
            out.write("\n")
        else:
            for f in report.findings:
                print(f.render(), file=out)
            bad = len(report.unsuppressed)
            print(f"{report.files_scanned} file(s), "
                  f"{len(report.findings)} finding(s), "
                  f"{bad} unsuppressed", file=out)
    finally:
        if out is not sys.stdout:
            out.close()
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
