"""Project-specific invariant checkers.  Importing this package
registers every rule with ``repro.analysis.core.CHECKERS``."""
from repro.analysis.rules import (durability, epochs, exceptions,  # noqa: F401
                                  locks, protocol, timesource)
