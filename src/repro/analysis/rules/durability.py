"""durability-ordering rule: control-file writes must be crash-atomic.

CPR's stamped-cycle protocol is only sound if the control files that
name a cycle durable — ``manifest.json``, ``CURRENT``, ``COORDINATOR``,
``LEASE`` — are replaced atomically *after* their bytes are on disk:
write tmp, flush, ``fsync(file)``, ``os.replace``, ``fsync(dir)``
(``repro.core.checkpoint.atomic_write_text`` / ``atomic_json_dump``).
A raw ``open(path, "w")`` on one of these paths can be observed
truncated by a concurrently-recovering coordinator, and an
``os.replace`` without the surrounding fsyncs can survive the rename
while losing the contents (docs/recovery.md, "Durability ordering").

Two checks:

* any writable ``open()`` whose path expression mentions a durable
  control-file name is flagged — route it through the atomic helpers;
* any function calling ``os.replace``/``os.rename`` must also fsync
  before (the tmp file) and after (the directory) the rename, so the
  atomic helpers themselves pass and ad-hoc reimplementations fail.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import (Checker, Finding, Source, is_call_to,
                                 names_in, register, str_constants_in)

DURABLE_MARKERS = ("manifest.json", "CURRENT", "COORDINATOR", "LEASE")
FSYNC_NAMES = {"fsync", "fdatasync", "fsync_path"}


def _is_durable_path(expr: ast.AST) -> bool:
    for const in str_constants_in(expr):
        if any(marker in const for marker in DURABLE_MARKERS):
            return True
    for name in names_in(expr):
        if name.endswith("_PTR") or name in ("MANIFEST_NAME",):
            return True
    return False


def _write_mode(call: ast.Call) -> bool:
    mode = None
    if len(call.args) >= 2:
        mode = call.args[1]
    for kw in call.keywords:
        if kw.arg == "mode":
            mode = kw.value
    if mode is None:
        return False                      # default "r"
    return (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
            and any(c in mode.value for c in "wax+"))


@register
class DurabilityChecker(Checker):
    name = "durability-ordering"
    description = ("durable control files written via atomic_write_text/"
                   "atomic_json_dump, or the full write-fsync-replace-"
                   "fsync(dir) sequence")

    def check(self, src: Source) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            # raw writable open() on a durable control-file path
            if isinstance(node.func, ast.Name) and node.func.id == "open" \
                    and node.args and _write_mode(node) \
                    and _is_durable_path(node.args[0]):
                yield Finding(
                    rule=self.name, path=src.relpath, line=node.lineno,
                    message=("raw writable open() on a durable control "
                             "file: use atomic_write_text/atomic_json_dump "
                             "so recovery never observes a torn write"))
            # os.replace/os.rename without the surrounding fsyncs
            if is_call_to(node, "os", "replace") \
                    or is_call_to(node, "os", "rename"):
                fn = src.enclosing(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef))
                if not self._fsync_bracketed(fn, node):
                    yield Finding(
                        rule=self.name, path=src.relpath, line=node.lineno,
                        message=("os.replace without the full write -> "
                                 "fsync(file) -> replace -> fsync(dir) "
                                 "sequence: rename durability needs both "
                                 "fsyncs (see atomic_write_text)"))

    @staticmethod
    def _fsync_bracketed(fn, replace_call: ast.Call) -> bool:
        """True when the enclosing function fsyncs both before (the tmp
        file's bytes) and after (the directory entry) the rename."""
        if fn is None:
            return False
        before = after = False
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            is_fsync = (isinstance(sub.func, ast.Attribute)
                        and sub.func.attr in FSYNC_NAMES) or \
                       (isinstance(sub.func, ast.Name)
                        and sub.func.id in FSYNC_NAMES)
            if not is_fsync:
                continue
            if sub.lineno <= replace_call.lineno:
                before = True
            if sub.lineno >= replace_call.lineno:
                after = True
        return before and after
