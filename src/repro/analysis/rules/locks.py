"""lock-discipline rule: annotated fields stay under their lock.

Clang-thread-safety-style lexical checking for the writer fleet's
concurrency conventions:

* A field assignment annotated ``# guarded by: <lock>`` declares that
  every access to ``self.<field>`` in that class (and its subclasses in
  the same file) must happen lexically inside ``with self.<lock>:``.
  Two escape hatches: ``__init__`` (no concurrent readers exist yet)
  and functions whose ``def`` line carries ``# holds: <lock>`` — the
  documented convention for helpers that run with the lock already held
  (e.g. ``WriterSession._handle`` runs under ``self.lock``).
* No blocking call — socket send/recv/accept/connect, ``os.fsync``,
  ``join``, ``sleep`` — lexically inside a ``with self._monitor_lock:``
  block (or a ``# holds: _monitor_lock`` function).  The monitor lock
  serializes probe sweeps against fence/close/resize; blocking under it
  stalls failure detection fleet-wide.

Limitations (by design — this is a lexical check): accesses through a
local alias (``s = self; s.field``), ``acquire()``/``release()`` call
pairs, and blocking work reached *indirectly* through another call are
not tracked.  Suppress genuine cross-thread racy reads explicitly with
``# lint: allow[lock-discipline] <why>`` so they are visibly deliberate.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Set

from repro.analysis.core import Checker, Finding, Source, register

GUARD_RE = re.compile(r"#\s*guarded by:\s*([A-Za-z_]\w*)")
HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_]\w*)")

MONITOR_LOCKS = {"_monitor_lock"}
BLOCKING_ATTRS = {"send", "sendall", "recv", "recv_into", "accept",
                  "connect", "fsync", "fdatasync", "join", "sleep"}


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name) \
            and node.value.id == "self":
        return node.attr
    return None


@register
class LockDisciplineChecker(Checker):
    name = "lock-discipline"
    description = ("'# guarded by: <lock>' fields only touched under "
                   "'with self.<lock>'; no blocking calls while "
                   "_monitor_lock is held")

    def check(self, src: Source) -> Iterator[Finding]:
        guard_lines: Dict[int, str] = {}
        holds_lines: Dict[int, str] = {}
        def code_line(i: int) -> int:
            """A standalone comment annotates the next code line."""
            if not src.lines[i - 1].strip().startswith("#"):
                return i
            j = i + 1
            while j <= len(src.lines) \
                    and src.lines[j - 1].strip().startswith("#"):
                j += 1
            return j

        for i, line in enumerate(src.lines, start=1):
            m = GUARD_RE.search(line)
            if m:
                guard_lines[code_line(i)] = m.group(1)
            m = HOLDS_RE.search(line)
            if m:
                holds_lines[code_line(i)] = m.group(1)

        classes: Dict[str, ast.ClassDef] = {}
        own_guards: Dict[ast.ClassDef, Dict[str, str]] = {}
        holds: Dict[ast.AST, Set[str]] = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.ClassDef):
                classes[node.name] = node
            elif isinstance(node, (ast.Assign, ast.AnnAssign)) \
                    and node.lineno in guard_lines:
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for tgt in targets:
                    field = _self_attr(tgt)
                    if field is None:
                        continue
                    cls = src.enclosing(node, ast.ClassDef)
                    if cls is not None:
                        own_guards.setdefault(cls, {})[field] = \
                            guard_lines[node.lineno]
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.lineno in holds_lines:
                holds.setdefault(node, set()).add(holds_lines[node.lineno])

        def effective_guards(cls: ast.ClassDef,
                             seen: Set[str]) -> Dict[str, str]:
            out: Dict[str, str] = {}
            for base in cls.bases:
                if isinstance(base, ast.Name) and base.id in classes \
                        and base.id not in seen:
                    out.update(effective_guards(
                        classes[base.id], seen | {base.id}))
            out.update(own_guards.get(cls, {}))
            return out

        for cls in classes.values():
            guards = effective_guards(cls, {cls.name})
            if not guards:
                continue
            for item in cls.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield from self._check_method(
                        src, guards, holds.get(item, set()), item)

        yield from self._check_monitor_blocking(src, holds)

    # -- guarded-field enforcement --------------------------------------
    def _check_method(self, src: Source, guards: Dict[str, str],
                      held: Set[str], fn) -> Iterator[Finding]:
        if fn.name == "__init__":
            return

        def visit(node: ast.AST, active: Set[str]):
            if isinstance(node, ast.With):
                inner = set(active)
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    if attr is not None:
                        inner.add(attr)
                for item in node.items:
                    yield from visit(item, active)
                for child in node.body:
                    yield from visit(child, inner)
                return
            field = _self_attr(node)
            if field is not None and field in guards:
                lock = guards[field]
                if lock not in active and lock not in held:
                    yield Finding(
                        rule=self.name, path=src.relpath, line=node.lineno,
                        message=(f"self.{field} is '# guarded by: {lock}' "
                                 f"but is accessed outside 'with "
                                 f"self.{lock}' (and {fn.name}() is not "
                                 f"annotated '# holds: {lock}')"))
            for child in ast.iter_child_nodes(node):
                yield from visit(child, active)

        for stmt in fn.body:
            yield from visit(stmt, set())

    # -- no blocking calls under the monitor lock -----------------------
    def _check_monitor_blocking(self, src: Source,
                                holds: Dict[ast.AST, Set[str]]
                                ) -> Iterator[Finding]:
        regions: List[ast.AST] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.With):
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    if attr in MONITOR_LOCKS:
                        regions.extend(node.body)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if holds.get(node, set()) & MONITOR_LOCKS:
                    regions.extend(node.body)
        for region in regions:
            for node in ast.walk(region):
                if not isinstance(node, ast.Call):
                    continue
                blocked = None
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in BLOCKING_ATTRS:
                    # ", ".join(...) is not thread-blocking
                    if not (isinstance(node.func.value, ast.Constant)
                            and isinstance(node.func.value.value, str)):
                        blocked = node.func.attr
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in ("sleep", "fsync"):
                    blocked = node.func.id
                if blocked is not None:
                    yield Finding(
                        rule=self.name, path=src.relpath, line=node.lineno,
                        message=(f"blocking call '{blocked}(...)' while "
                                 f"holding _monitor_lock: the monitor lock "
                                 f"serializes probe sweeps against fences "
                                 f"-- blocking here stalls failure "
                                 f"detection fleet-wide"))
