"""protocol-conformance + wire-doc-drift: both protocol sides vs the spec.

The wire protocol's single source of truth is
``repro.analysis.protocol.spec``.  These rules keep the implementation
and the docs from drifting away from it:

* **protocol-conformance** — extracts every frame construction site
  (tuple literals reaching ``send``-family calls, plus every
  spec-kind tuple literal inside the two protocol files) and every
  dispatch site (comparisons against ``msg[0]`` / ``kind``,
  ``_recv_until("kind", ...)`` waits) on both sides — client
  ``*Endpoint`` / ``*Connection`` classes and ``client_hello``, server
  ``WriterSession`` / ``shard_server`` demux loop — and verifies each
  against the spec: the kind exists, the arity is inside the spec
  range, the direction matches the side constructing it, and
  coordinator->worker frames thread the epoch through the spec's
  declared slot.  Cross-file, it checks *completeness*: every
  coordinator->worker kind must be constructed client-side and
  dispatched server-side, every worker->coordinator kind constructed
  server-side and dispatched client-side (envelopes on both).  This
  supersedes the epoch-threading rule's frame-drift half: adding,
  renaming, or resizing a frame on one side only fails analysis.
* **wire-doc-drift** — the wire table in ``docs/recovery.md`` between
  the ``<!-- wire-spec:begin/end -->`` markers must be exactly
  ``render_wire_table()``; regenerate with
  ``python -m repro.analysis.protocol --write-table``.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Checker, Finding, Source, names_in, register
from repro.analysis.protocol import spec as wire

SEND_FUNCS = {"_send", "_send_raw", "send", "send_for", "put",
              "put_nowait"}

# the two files that ARE the protocol implementation
_PROTOCOL_FILES = ("core/transport.py", "launch/shard_server.py")
_SERVER_FILE = "launch/shard_server.py"

CLIENT = "client"
SERVER = "server"


def _is_protocol_file(relpath: str) -> bool:
    return any(relpath.endswith(p) for p in _PROTOCOL_FILES)


def _head_kind(tup: ast.Tuple) -> Optional[str]:
    if tup.elts and isinstance(tup.elts[0], ast.Constant) \
            and isinstance(tup.elts[0].value, str):
        return tup.elts[0].value
    return None


def _side_of(src: Source, node: ast.AST) -> Optional[str]:
    """Which protocol side a construction/dispatch site belongs to —
    None when the site is neither (helpers, payload plumbing)."""
    cls = src.enclosing(node, ast.ClassDef)
    if cls is not None:
        if "Session" in cls.name:
            return SERVER
        if cls.name.endswith(("Endpoint", "Connection")) \
                or cls.name == "_MuxChan":
            return CLIENT
        if src.relpath.endswith(_SERVER_FILE):
            return SERVER
        return None
    if src.relpath.endswith(_SERVER_FILE):
        return SERVER
    fn = src.enclosing(node, (ast.FunctionDef, ast.AsyncFunctionDef))
    if fn is not None and fn.name == "client_hello":
        return CLIENT
    return None


def _specs_for_side(kind: str, side: Optional[str]):
    if side == CLIENT:
        return wire.frames_for(kind, wire.C2W)
    if side == SERVER:
        return wire.frames_for(kind, wire.W2C)
    return wire.frames_for(kind)


@register
class ProtocolConformanceChecker(Checker):
    name = "protocol-conformance"
    description = ("every frame construction and dispatch site on both "
                   "protocol sides conforms to the wire spec (kind, "
                   "arity, epoch slot, direction, completeness)")

    def __init__(self):
        # side -> kind -> [(relpath, line)]
        self.constructed: Dict[str, Dict[str, List[Tuple[str, int]]]] = {
            CLIENT: {}, SERVER: {}}
        self.dispatched: Dict[str, Dict[str, List[Tuple[str, int]]]] = {
            CLIENT: {}, SERVER: {}}
        self._spec_relpath: Optional[str] = None
        self._protocol_files_seen: Set[str] = set()

    # ------------------------------------------------------------ check
    def check(self, src: Source) -> Iterator[Finding]:
        if src.relpath.endswith("analysis/protocol/spec.py"):
            self._spec_relpath = src.relpath
        for p in _PROTOCOL_FILES:
            if src.relpath.endswith(p):
                self._protocol_files_seen.add(p)
        in_proto = _is_protocol_file(src.relpath)
        seen_tuples = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                yield from self._check_send(src, node, seen_tuples)
                self._collect_recv_until(src, node)
            elif isinstance(node, ast.Compare):
                yield from self._check_dispatch(src, node, in_proto)
        if in_proto:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.Tuple) \
                        and id(node) not in seen_tuples \
                        and not isinstance(getattr(node, "parent", None),
                                           ast.Compare):
                    yield from self._check_tuple(src, node,
                                                 check_epoch=False)

    # -- constructions --------------------------------------------------
    def _check_send(self, src, call: ast.Call, seen_tuples):
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in SEND_FUNCS and call.args):
            return
        tup = next((a for a in call.args if isinstance(a, ast.Tuple)),
                   None)
        if tup is None:
            return
        seen_tuples.add(id(tup))
        cls = src.enclosing(call, ast.ClassDef)
        endpoint_cls = cls is not None and cls.name.endswith("Endpoint")
        if not (endpoint_cls or _is_protocol_file(src.relpath)):
            return                      # tests/fuzzers send junk on purpose
        kind = _head_kind(tup)
        if kind is None:
            return
        if kind not in wire.KINDS:
            yield Finding(
                rule=self.name, path=src.relpath, line=call.lineno,
                message=(f"frame kind {kind!r} is not in the wire spec "
                         f"(repro.analysis.protocol.spec): declare it "
                         f"there first, then both sides"))
            return
        yield from self._check_tuple(src, tup, check_epoch=True,
                                     line=call.lineno)

    def _check_tuple(self, src, tup: ast.Tuple, check_epoch: bool,
                     line: Optional[int] = None):
        kind = _head_kind(tup)
        if kind is None or kind not in wire.KINDS:
            return
        if any(isinstance(e, ast.Starred) for e in tup.elts):
            return                      # arity unknowable statically
        line = line or tup.lineno
        side = _side_of(src, tup)
        specs = _specs_for_side(kind, side)
        if not specs:
            # the kind exists but not for this side's direction
            legal = ", ".join(s.direction for s in wire.frames_for(kind))
            yield Finding(
                rule=self.name, path=src.relpath, line=line,
                message=(f"frame {kind!r} constructed on the {side} side "
                         f"but the spec declares it {legal}-only"))
            return
        n = len(tup.elts)
        if not any(s.min_arity <= n <= s.max_arity for s in specs):
            want = "/".join(
                (str(s.min_arity) if s.min_arity == s.max_arity
                 else f"{s.min_arity}..{s.max_arity}") for s in specs)
            yield Finding(
                rule=self.name, path=src.relpath, line=line,
                message=(f"frame {kind!r} constructed with arity {n}, "
                         f"spec says {want}"))
            return
        if side is not None:
            self.constructed[side].setdefault(kind, []).append(
                (src.relpath, line))
        if not (check_epoch and side == CLIENT):
            return
        for s in specs:
            if s.epoch_slot is None or s.direction != wire.C2W:
                continue
            if n <= s.epoch_slot or not any(
                    "epoch" in nm for nm in names_in(tup.elts[s.epoch_slot])):
                yield Finding(
                    rule=self.name, path=src.relpath, line=line,
                    message=(f"frame {kind!r} does not thread the "
                             f"coordinator epoch through spec slot "
                             f"{s.epoch_slot} ({s.fields[s.epoch_slot]}): "
                             f"the stale-coordinator fence cannot see it"))

    # -- dispatch sites -------------------------------------------------
    def _check_dispatch(self, src, cmp: ast.Compare, in_proto: bool):
        left = cmp.comparators and cmp.left
        is_kind_expr = (
            (isinstance(left, ast.Name) and left.id in ("kind", "want"))
            or (isinstance(left, ast.Subscript)
                and isinstance(left.slice, ast.Constant)
                and left.slice.value == 0))
        if not is_kind_expr or len(cmp.ops) != 1:
            return
        if not isinstance(cmp.ops[0],
                          (ast.Eq, ast.NotEq, ast.In, ast.NotIn)):
            return
        rhs = cmp.comparators[0]
        kinds: List[str] = []
        if isinstance(rhs, ast.Constant) and isinstance(rhs.value, str):
            kinds = [rhs.value]
        elif isinstance(rhs, (ast.Tuple, ast.List, ast.Set)):
            kinds = [e.value for e in rhs.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str)]
        if not kinds:
            return
        cls = src.enclosing(cmp, ast.ClassDef)
        protocol_cls = cls is not None and (
            "Session" in cls.name
            or cls.name.endswith(("Endpoint", "Connection")))
        # Name-form comparisons outside protocol classes dispatch on
        # payload/manifest kinds, not wire frames — leave them alone.
        if isinstance(left, ast.Name) and not protocol_cls:
            return
        if not (protocol_cls or in_proto):
            return
        side = _side_of(src, cmp)
        for kind in kinds:
            if kind not in wire.KINDS:
                yield Finding(
                    rule=self.name, path=src.relpath, line=cmp.lineno,
                    message=(f"dispatch references frame kind {kind!r} "
                             f"that is not in the wire spec: dead "
                             f"protocol arm or an undeclared frame"))
            elif side is not None and in_proto:
                self.dispatched[side].setdefault(kind, []).append(
                    (src.relpath, cmp.lineno))

    def _collect_recv_until(self, src, call: ast.Call):
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr == "_recv_until" and call.args
                and isinstance(call.args[0], ast.Constant)
                and isinstance(call.args[0].value, str)):
            return
        if not _is_protocol_file(src.relpath):
            return
        kind = call.args[0].value
        side = _side_of(src, call) or CLIENT   # replies are client waits
        if kind in wire.KINDS:
            self.dispatched[side].setdefault(kind, []).append(
                (src.relpath, call.lineno))

    # -- cross-file completeness ---------------------------------------
    def finalize(self, sources: Sequence[Source]) -> Iterator[Finding]:
        if set(self._protocol_files_seen) != set(_PROTOCOL_FILES):
            return          # partial scan (corpus/unit fixtures): skip
        anchor = self._spec_relpath or _PROTOCOL_FILES[0]
        for key, f in sorted(wire.FRAMES.items()):
            kind, direction = key
            if direction in (wire.C2W, wire.BOTH):
                yield from self._require(
                    anchor, kind, self.constructed[CLIENT],
                    "constructed on the client (*Endpoint) side")
                yield from self._require(
                    anchor, kind, self.dispatched[SERVER],
                    "dispatched on the server "
                    "(WriterSession/shard_server) side")
            if direction in (wire.W2C, wire.BOTH):
                yield from self._require(
                    anchor, kind, self.constructed[SERVER],
                    "constructed on the server side")
                yield from self._require(
                    anchor, kind, self.dispatched[CLIENT],
                    "dispatched on the client (reply) side")

    def _require(self, anchor, kind, table, what):
        if kind not in table:
            yield Finding(
                rule=self.name, path=anchor, line=1,
                message=(f"spec frame {kind!r} is never {what}: "
                         f"protocol drift between the spec and the "
                         f"implementation"))


@register
class WireDocDriftChecker(Checker):
    name = "wire-doc-drift"
    description = ("the wire table in docs/recovery.md matches the "
                   "machine-readable spec verbatim")

    def finalize(self, sources: Sequence[Source]) -> Iterator[Finding]:
        spec_src = next(
            (s for s in sources
             if s.relpath.endswith("analysis/protocol/spec.py")), None)
        if spec_src is None:
            return                      # spec not in this scan: no opinion
        # <repo>/src/repro/analysis/protocol/spec.py -> <repo>/docs/...
        repo = spec_src.abspath
        for _ in range(5):
            repo = os.path.dirname(repo)
        doc = os.path.join(repo, "docs", "recovery.md")
        regen = ("regenerate with `python -m repro.analysis.protocol "
                 "--write-table`")
        if not os.path.exists(doc):
            yield Finding(
                rule=self.name, path=spec_src.relpath, line=1,
                message=f"docs/recovery.md not found at {doc}; {regen}")
            return
        with open(doc, encoding="utf-8") as f:
            text = f.read()
        begin, end = wire.WIRE_TABLE_BEGIN, wire.WIRE_TABLE_END
        if begin not in text or end not in text:
            yield Finding(
                rule=self.name, path=spec_src.relpath, line=1,
                message=(f"docs/recovery.md is missing the "
                         f"{begin} / {end} markers; {regen}"))
            return
        embedded = text.split(begin, 1)[1].split(end, 1)[0].strip("\n")
        want = wire.render_wire_table().strip("\n")
        if embedded != want:
            got_l, want_l = embedded.splitlines(), want.splitlines()
            diff = next(
                (i for i, (a, b) in enumerate(zip(got_l, want_l))
                 if a != b), min(len(got_l), len(want_l)))
            yield Finding(
                rule=self.name, path=spec_src.relpath, line=1,
                message=(f"docs/recovery.md wire table disagrees with "
                         f"the spec (first divergence at embedded table "
                         f"line {diff + 1}); {regen}"))
