"""epoch-threading rule: every frame carries the epoch; no protocol drift.

The coordinator-epoch fence (docs/recovery.md) only works if *every*
coordinator→worker frame carries the coordinator epoch where the worker
expects it: command frames at index 1 (``WriterSession._handle`` reads
``msg[1]``), ``spawn`` in its keyword slot.  A frame constructed without
the epoch is invisible to the stale-coordinator guard — a superseded
coordinator could keep writing through it after a takeover.

Two checks, both over tuple-literal frames constructed inside classes
whose name ends with ``Endpoint`` (the coordinator-side senders):

* **epoch field** — every command frame's index-1 element (``spawn``:
  any element) must reference an ``epoch`` attribute/name;
* **protocol drift** — every constructed frame kind must be handled
  somewhere outside the Endpoint classes (the worker dispatch:
  ``WriterSession._handle``, ``shard_server``), and every kind a
  ``*Session`` dispatch handles must still have a constructor.  Adding
  a frame type on one side only is exactly the bug this catches.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Sequence, Set, Tuple

from repro.analysis.core import Checker, Finding, Source, names_in, register

SEND_FUNCS = {"_send", "_send_raw", "send", "put", "put_nowait"}


def _kind_of(tup: ast.Tuple):
    if tup.elts and isinstance(tup.elts[0], ast.Constant) \
            and isinstance(tup.elts[0].value, str):
        return tup.elts[0].value
    return None


def _mentions_epoch(node: ast.AST) -> bool:
    return any("epoch" in n for n in names_in(node))


@register
class EpochThreadingChecker(Checker):
    name = "epoch-threading"
    description = ("coordinator frames carry the epoch at index 1; frame "
                   "kinds stay in sync with the worker dispatch tables")

    def __init__(self):
        # kind -> [(relpath, lineno, epoch_ok)]
        self.sent: Dict[str, List[Tuple[str, int, bool]]] = {}
        # kind -> [(relpath, lineno)], split by dispatch locality
        self.handled: Set[str] = set()
        self.session_handled: Dict[str, List[Tuple[str, int]]] = {}

    def check(self, src: Source) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                self._collect_send(src, node)
            elif isinstance(node, ast.Compare):
                self._collect_handled(src, node)
        return iter(())

    # -- frame constructors (coordinator side) --------------------------
    def _collect_send(self, src: Source, call: ast.Call):
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in SEND_FUNCS and call.args
                and isinstance(call.args[0], ast.Tuple)):
            return
        cls = src.enclosing(call, ast.ClassDef)
        if cls is None or not cls.name.endswith("Endpoint"):
            return
        tup = call.args[0]
        kind = _kind_of(tup)
        if kind is None:
            return
        if kind == "spawn":
            epoch_ok = any(_mentions_epoch(e) for e in tup.elts)
        else:
            epoch_ok = len(tup.elts) >= 2 and _mentions_epoch(tup.elts[1])
        self.sent.setdefault(kind, []).append(
            (src.relpath, call.lineno, epoch_ok))

    # -- dispatch tables (worker side) ----------------------------------
    def _collect_handled(self, src: Source, cmp: ast.Compare):
        left = cmp.comparators and cmp.left
        is_kind_expr = (
            (isinstance(left, ast.Name) and left.id in ("kind",))
            or (isinstance(left, ast.Subscript)
                and isinstance(left.slice, ast.Constant)
                and left.slice.value == 0))
        if not is_kind_expr or len(cmp.ops) != 1:
            return
        if not isinstance(cmp.ops[0], (ast.Eq, ast.In, ast.NotIn)):
            return
        rhs = cmp.comparators[0]
        kinds: List[str] = []
        if isinstance(rhs, ast.Constant) and isinstance(rhs.value, str):
            kinds = [rhs.value]
        elif isinstance(rhs, (ast.Tuple, ast.List, ast.Set)):
            kinds = [e.value for e in rhs.elts
                     if isinstance(e, ast.Constant)
                     and isinstance(e.value, str)]
        if not kinds:
            return
        cls = src.enclosing(cmp, ast.ClassDef)
        if cls is not None and cls.name.endswith("Endpoint"):
            return      # coordinator-side reply dispatch, not the workers
        self.handled.update(kinds)
        if cls is not None and "Session" in cls.name:
            for k in kinds:
                self.session_handled.setdefault(k, []).append(
                    (src.relpath, cmp.lineno))

    # -- cross-file reconciliation --------------------------------------
    def finalize(self, sources: Sequence[Source]) -> Iterator[Finding]:
        for kind, sites in sorted(self.sent.items()):
            for relpath, lineno, epoch_ok in sites:
                if not epoch_ok:
                    yield Finding(
                        rule=self.name, path=relpath, line=lineno,
                        message=(f"frame {kind!r} constructed without the "
                                 f"coordinator epoch at index 1: the "
                                 f"stale-coordinator guard cannot fence "
                                 f"this command"))
                if kind not in self.handled:
                    yield Finding(
                        rule=self.name, path=relpath, line=lineno,
                        message=(f"frame kind {kind!r} is constructed but "
                                 f"no worker dispatch handles it: protocol "
                                 f"drift between transport and "
                                 f"shard_server"))
        for kind, sites in sorted(self.session_handled.items()):
            if kind in self.sent:
                continue
            for relpath, lineno in sites:
                yield Finding(
                    rule=self.name, path=relpath, line=lineno,
                    message=(f"dispatch handles frame kind {kind!r} but no "
                             f"endpoint constructs it: dead protocol arm "
                             f"or a renamed frame left behind"))
