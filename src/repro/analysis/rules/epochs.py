"""epoch-threading rule: every frame carries the coordinator epoch.

The coordinator-epoch fence (docs/recovery.md) only works if *every*
coordinator→worker frame carries the coordinator epoch where the worker
expects it: command frames at index 1 (``WriterSession._handle`` reads
``msg[1]``), ``spawn`` in its keyword slot.  A frame constructed without
the epoch is invisible to the stale-coordinator guard — a superseded
coordinator could keep writing through it after a takeover.

One check, over tuple-literal frames constructed inside classes whose
name ends with ``Endpoint`` (the coordinator-side senders): every
command frame's index-1 element (``spawn``: any element) must reference
an ``epoch`` attribute/name.

The former *protocol drift* half of this rule (frame kinds constructed
vs handled) is superseded by ``protocol-conformance``
(``rules/protocol.py``), which checks kinds, arities, epoch slots, and
cross-side completeness against the machine-readable wire spec.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Sequence, Tuple

from repro.analysis.core import Checker, Finding, Source, names_in, register

SEND_FUNCS = {"_send", "_send_raw", "send", "put", "put_nowait"}


def _kind_of(tup: ast.Tuple):
    if tup.elts and isinstance(tup.elts[0], ast.Constant) \
            and isinstance(tup.elts[0].value, str):
        return tup.elts[0].value
    return None


def _mentions_epoch(node: ast.AST) -> bool:
    return any("epoch" in n for n in names_in(node))


@register
class EpochThreadingChecker(Checker):
    name = "epoch-threading"
    description = ("coordinator frames carry the epoch at index 1 "
                   "(frame-kind drift lives in protocol-conformance)")

    def __init__(self):
        # kind -> [(relpath, lineno, epoch_ok)]
        self.sent: Dict[str, List[Tuple[str, int, bool]]] = {}

    def check(self, src: Source) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call):
                self._collect_send(src, node)
        return iter(())

    # -- frame constructors (coordinator side) --------------------------
    def _collect_send(self, src: Source, call: ast.Call):
        if not (isinstance(call.func, ast.Attribute)
                and call.func.attr in SEND_FUNCS and call.args
                and isinstance(call.args[0], ast.Tuple)):
            return
        cls = src.enclosing(call, ast.ClassDef)
        if cls is None or not cls.name.endswith("Endpoint"):
            return
        tup = call.args[0]
        kind = _kind_of(tup)
        if kind is None:
            return
        if kind == "spawn":
            epoch_ok = any(_mentions_epoch(e) for e in tup.elts)
        else:
            epoch_ok = len(tup.elts) >= 2 and _mentions_epoch(tup.elts[1])
        self.sent.setdefault(kind, []).append(
            (src.relpath, call.lineno, epoch_ok))

    # -- reporting ------------------------------------------------------
    def finalize(self, sources: Sequence[Source]) -> Iterator[Finding]:
        for kind, sites in sorted(self.sent.items()):
            for relpath, lineno, epoch_ok in sites:
                if not epoch_ok:
                    yield Finding(
                        rule=self.name, path=relpath, line=lineno,
                        message=(f"frame {kind!r} constructed without the "
                                 f"coordinator epoch at index 1: the "
                                 f"stale-coordinator guard cannot fence "
                                 f"this command"))
