"""exception-hygiene rule: no silent swallowing in the protocol paths.

A broad ``except Exception`` in the fence/stamp/attach paths that
neither re-raises, latches the error (``self.err`` / ``self._exc`` /
``self.failed[...]``), nor poisons the endpoint converts a shard-writer
failure into silent data loss: the coordinator stamps a cycle whose
shard never hit disk.  Narrow the handler, latch the error, or annotate
the handler line with ``# lint: allow[exception-hygiene] <why>`` when
swallowing is the contract (e.g. ``close()`` must never raise).

Scope: the protocol code — ``core/`` and ``launch/shard_server.py``.
Best-effort cleanup in launch scripts and benchmarks is out of scope.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import Checker, Finding, Source, register

BROAD = {"Exception", "BaseException"}
LATCH_CALLS = {"poison", "_latch"}
LATCH_TARGETS = {"err", "_exc", "_broken", "failed", "shard_failures",
                 "_pending_poison"}


def _in_scope(relpath: str) -> bool:
    return relpath.startswith("core/") or relpath == "launch/shard_server.py"


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True                       # bare except:
    if isinstance(t, ast.Name) and t.id in BROAD:
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name) and e.id in BROAD for e in t.elts)
    return False


def _handles_error(handler: ast.ExceptHandler) -> bool:
    """Body re-raises, latches, or poisons — the failure stays visible."""
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                and node.func.attr in LATCH_CALLS:
            return True
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                for sub in ast.walk(tgt):
                    if isinstance(sub, ast.Attribute) \
                            and sub.attr in LATCH_TARGETS:
                        return True
                    if isinstance(sub, ast.Name) and sub.id in LATCH_TARGETS:
                        return True
                    # box["err"] = e: latched for a later join to surface
                    if isinstance(sub, ast.Constant) \
                            and sub.value in ("err", "error", "_exc"):
                        return True
    return False


@register
class ExceptionHygieneChecker(Checker):
    name = "exception-hygiene"
    description = ("broad except in fence/stamp/attach paths must latch, "
                   "poison, or re-raise -- never swallow silently")

    def check(self, src: Source) -> Iterator[Finding]:
        if not _in_scope(src.relpath):
            return
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if _is_broad(node) and not _handles_error(node):
                yield Finding(
                    rule=self.name, path=src.relpath, line=node.lineno,
                    message=("broad except swallows the error without "
                             "latching, poisoning, or re-raising: narrow "
                             "it, latch it, or annotate why swallowing "
                             "is the contract"))
