"""time-source rule: wall clock only for persisted records and lease math.

Internal deadlines, back-offs and duration measurements must use
``time.monotonic()`` — ``time.time()`` jumps under NTP step/slew and
would corrupt probe deadlines and fence timeouts (this generalizes the
PR 5 guard test that lived in ``tests/test_transport.py``).

``time.time()`` stays legal in exactly two places:

* values stored under a persisted ``"time"`` / ``"expires"`` key
  (manifest events, lease records) — human-readable provenance and
  cross-process lease expiry must survive restarts, so they must be
  wall-clock;
* lease arithmetic comparing against a persisted ``"expires"`` stamp.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.core import (Checker, Finding, Source, is_call_to,
                                 register, str_constants_in)

PERSIST_KEYS = {"time", "expires"}


@register
class TimeSourceChecker(Checker):
    name = "time-source"
    description = ("time.time() only in persisted records and lease math; "
                  "time.monotonic() for deadlines, back-offs, durations")

    def check(self, src: Source) -> Iterator[Finding]:
        for node in ast.walk(src.tree):
            if not is_call_to(node, "time", "time"):
                continue
            if self._allowed(src, node):
                continue
            yield Finding(
                rule=self.name, path=src.relpath, line=node.lineno,
                message=("time.time() outside a persisted record or lease "
                         "math: use time.monotonic() for deadlines and "
                         "durations"))

    def _allowed(self, src: Source, call: ast.Call) -> bool:
        # (a) dict value stored under a persisted key:
        #     {"time": time.time()} / {"expires": time.time() + ttl}
        prev: ast.AST = call
        for anc in src.ancestors(call):
            if isinstance(anc, ast.Dict):
                for key, value in zip(anc.keys, anc.values):
                    if value is prev and isinstance(key, ast.Constant) \
                            and key.value in PERSIST_KEYS:
                        return True
            if isinstance(anc, ast.stmt):
                break
            prev = anc

        stmt = src.enclosing_statement(call)
        # (b) subscript store under a persisted key:
        #     ev["time"] = time.time()
        if isinstance(stmt, ast.Assign):
            for target in stmt.targets:
                if isinstance(target, ast.Subscript):
                    sl = target.slice
                    if isinstance(sl, ast.Constant) \
                            and sl.value in PERSIST_KEYS:
                        return True
        # (c) lease math against a persisted expiry stamp:
        #     rec.get("expires", 0) > time.time()
        if any(c == "expires" for c in str_constants_in(stmt)):
            return True
        return False
