"""Invariant-linter core: sources, findings, suppressions, baseline, runner.

The CPR writer fleet's safety argument rests on conventions no general
linter knows about — fsync-before-STAMP ordering, monotonic deadlines,
epoch-fenced frames, ``_monitor_lock`` discipline.  This module is the
engine that project-specific checkers (``repro.analysis.rules``) plug
into:

* ``Source`` — one parsed Python file: text, line table, AST with parent
  links, and the per-line suppression map.
* ``Checker`` — base class; subclasses register with ``@register`` and
  implement ``check`` (per file) and/or ``finalize`` (cross-file, e.g.
  the frame-type drift check needs both sides of the wire protocol).
* ``run_analysis`` — walk a tree, run checkers, apply suppressions and
  an optional findings baseline, return a ``Report``.

Suppression syntax (same line as the finding, or a standalone comment
line directly above it)::

    risky_thing()   # lint: allow[rule-name] why this one is fine

Baseline: a JSON list of ``{rule, path, message}`` records.  Matching
deliberately ignores line numbers so unrelated edits above a grand-
fathered finding do not resurrect it.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence

SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*allow\[([A-Za-z0-9_-]+)\]\s*(.*?)\s*$")


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location."""
    rule: str
    path: str               # relative to the scan root
    line: int
    message: str
    suppressed: bool = False
    suppress_reason: str = ""
    baselined: bool = False

    @property
    def key(self):
        # line numbers churn; identity is (rule, file, message)
        return (self.rule, self.path, self.message)

    def render(self) -> str:
        tags = []
        if self.suppressed:
            tags.append("allowed: " + (self.suppress_reason or "no reason"))
        if self.baselined:
            tags.append("baselined")
        tag = f"  [{'; '.join(tags)}]" if tags else ""
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}{tag}"


class Source:
    """A parsed source file plus the metadata checkers need."""

    def __init__(self, root: str, abspath: str):
        self.root = root
        self.abspath = abspath
        self.relpath = os.path.relpath(abspath, root).replace(os.sep, "/")
        with open(abspath, encoding="utf-8") as f:
            self.text = f.read()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=self.relpath)
        self._link_parents()
        self._suppressions = self._parse_suppressions()

    def _link_parents(self):
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child.parent = node  # type: ignore[attr-defined]

    def _parse_suppressions(self) -> Dict[int, Dict[str, str]]:
        """line number -> {rule: reason}.  A suppression comment covers
        its own line; a comment-only line also covers the next line."""
        out: Dict[int, Dict[str, str]] = {}
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rule, reason = m.group(1), m.group(2)
            out.setdefault(i, {})[rule] = reason
            if line.strip().startswith("#"):
                # a standalone comment covers the next code line, skipping
                # over any continuation comment lines below it
                j = i + 1
                while j <= len(self.lines) \
                        and self.lines[j - 1].strip().startswith("#"):
                    j += 1
                out.setdefault(j, {})[rule] = reason
        return out

    def suppression(self, line: int, rule: str) -> Optional[str]:
        """Reason string if ``line`` carries an allow for ``rule``."""
        rules = self._suppressions.get(line)
        if rules is None:
            return None
        return rules.get(rule)

    # -- AST helpers shared by checkers ---------------------------------
    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = getattr(node, "parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "parent", None)

    def enclosing(self, node: ast.AST, kinds) -> Optional[ast.AST]:
        for anc in self.ancestors(node):
            if isinstance(anc, kinds):
                return anc
        return None

    def enclosing_statement(self, node: ast.AST) -> ast.AST:
        cur = node
        while not isinstance(cur, ast.stmt):
            nxt = getattr(cur, "parent", None)
            if nxt is None:
                break
            cur = nxt
        return cur


class Checker:
    """Base class for invariant checkers.

    ``check`` runs once per file; ``finalize`` runs once per analysis
    with every scanned ``Source`` — use it for cross-file invariants.
    """

    name = ""
    description = ""

    def check(self, src: Source) -> Iterator[Finding]:
        return iter(())

    def finalize(self, sources: Sequence[Source]) -> Iterator[Finding]:
        return iter(())


CHECKERS: Dict[str, type] = {}


def register(cls):
    """Class decorator: add a Checker subclass to the registry."""
    assert cls.name and cls.name not in CHECKERS, cls
    CHECKERS[cls.name] = cls
    return cls


# ---------------------------------------------------------------------------
# helpers commonly needed by rules


def is_call_to(node: ast.AST, modname: str, attr: str) -> bool:
    """True for ``modname.attr(...)`` calls (e.g. ``time.time()``)."""
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == attr
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id == modname)


def names_in(node: ast.AST) -> Iterator[str]:
    """Every Name id and Attribute attr in a subtree."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


def str_constants_in(node: ast.AST) -> Iterator[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value


# ---------------------------------------------------------------------------
# runner


@dataclasses.dataclass
class Report:
    root: str
    findings: List[Finding]
    files_scanned: int = 0

    @property
    def unsuppressed(self) -> List[Finding]:
        return [f for f in self.findings
                if not f.suppressed and not f.baselined]

    @property
    def ok(self) -> bool:
        return not self.unsuppressed

    def to_json(self) -> dict:
        return {
            "root": self.root,
            "files_scanned": self.files_scanned,
            "counts": {
                "total": len(self.findings),
                "suppressed": sum(f.suppressed for f in self.findings),
                "baselined": sum(f.baselined for f in self.findings),
                "unsuppressed": len(self.unsuppressed),
            },
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }

    def to_sarif(self) -> dict:
        """SARIF 2.1.0 (the GitHub code-scanning dialect): one run, one
        rule entry per registered checker, one result per finding.
        Suppressed findings carry ``suppressions: [{kind: inSource}]``
        so upload surfaces them as dismissed, not open."""
        rule_ids = sorted({f.rule for f in self.findings}
                          | set(CHECKERS.keys()))
        rules = [{
            "id": rid,
            "shortDescription": {
                "text": getattr(CHECKERS.get(rid), "description", rid)
                or rid},
        } for rid in rule_ids]
        results = []
        for f in self.findings:
            result = {
                "ruleId": f.rule,
                "level": "note" if (f.suppressed or f.baselined)
                         else "error",
                "message": {"text": f.message},
                "locations": [{
                    "physicalLocation": {
                        "artifactLocation": {"uri": f.path},
                        "region": {"startLine": max(1, f.line)},
                    },
                }],
            }
            if f.suppressed:
                result["suppressions"] = [{
                    "kind": "inSource",
                    "justification": f.suppress_reason or "no reason",
                }]
            elif f.baselined:
                result["suppressions"] = [{"kind": "external"}]
            results.append(result)
        return {
            "$schema": ("https://raw.githubusercontent.com/oasis-tcs/"
                        "sarif-spec/master/Schemata/sarif-schema-2.1.0"
                        ".json"),
            "version": "2.1.0",
            "runs": [{
                "tool": {"driver": {
                    "name": "repro-invariant-linter",
                    "rules": rules,
                }},
                "results": results,
            }],
        }

    def baseline_records(self) -> List[dict]:
        keys = sorted({f.key for f in self.findings if not f.suppressed})
        return [{"rule": r, "path": p, "message": m} for (r, p, m) in keys]


def load_baseline(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        records = json.load(f)
    return {(r["rule"], r["path"], r["message"]) for r in records}


def write_baseline(report: Report, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(report.baseline_records(), f, indent=2, sort_keys=True)
        f.write("\n")


def default_root() -> str:
    """The installed ``repro`` package directory (src/repro in-tree)."""
    import repro
    return os.path.dirname(os.path.abspath(repro.__file__))


def iter_py_files(root: str) -> Iterator[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def run_analysis(root: Optional[str] = None,
                 rules: Optional[Iterable[str]] = None,
                 baseline: Optional[str] = None) -> Report:
    """Run the selected checkers (default: all) over every .py under
    ``root`` (default: the repro package) and return a ``Report``."""
    # rule modules self-register on import
    from repro.analysis import rules as _rules  # noqa: F401

    root = os.path.abspath(root or default_root())
    selected = sorted(rules) if rules else sorted(CHECKERS)
    unknown = [r for r in selected if r not in CHECKERS]
    if unknown:
        raise ValueError(f"unknown rule(s): {', '.join(unknown)} "
                         f"(known: {', '.join(sorted(CHECKERS))})")
    checkers = [CHECKERS[r]() for r in selected]

    sources: List[Source] = []
    findings: List[Finding] = []
    by_path: Dict[str, Source] = {}
    for path in iter_py_files(root):
        try:
            src = Source(root, path)
        except (SyntaxError, UnicodeDecodeError):
            continue                     # not analyzable; not our problem
        sources.append(src)
        by_path[src.relpath] = src

    for checker in checkers:
        for src in sources:
            findings.extend(checker.check(src))
        findings.extend(checker.finalize(sources))

    baseline_keys = load_baseline(baseline) if baseline else set()
    for f in findings:
        src = by_path.get(f.path)
        if src is not None:
            reason = src.suppression(f.line, f.rule)
            if reason is not None:
                f.suppressed = True
                f.suppress_reason = reason
        if not f.suppressed and f.key in baseline_keys:
            f.baselined = True

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(root=root, findings=findings, files_scanned=len(sources))
