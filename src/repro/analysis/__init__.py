"""Static + runtime invariant analysis for the CPR writer fleet.

``python -m repro.analysis`` runs the AST checkers (durability
ordering, time sources, lock discipline, epoch threading, exception
hygiene) over the ``repro`` package and exits non-zero on any
unsuppressed finding.  ``repro.analysis.lockorder`` is the opt-in
runtime lock-order sanitizer wired into the test suite via
``CPR_LOCK_SANITIZER=1`` (tests/conftest.py).  See docs/analysis.md.
"""
from repro.analysis.core import (CHECKERS, Checker, Finding, Report,  # noqa: F401
                                 Source, default_root, load_baseline,
                                 register, run_analysis, write_baseline)
