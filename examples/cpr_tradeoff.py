"""Explore the PLS knob: overhead vs accuracy across target-PLS values
(paper Fig. 9), plus the analytic benefit analysis (paper Fig. 5 logic).

  PYTHONPATH=src python examples/cpr_tradeoff.py
"""
from repro.configs.dlrm import DLRM_KAGGLE, scaled
from repro.core import (CPRManager, Emulator, FailureInjector, SystemParams,
                        choose_strategy)
from repro.data.synthetic import ClickLogDataset

p = SystemParams()
print("Analytic benefit analysis (paper Fig. 5 / §4.1):")
for pls in (0.01, 0.05, 0.1, 0.2):
    d = choose_strategy(p, pls)
    print(f"  target PLS={pls:<5} -> T_save={d['T_save']:5.1f}h  "
          f"partial={d['use_partial']}  "
          f"overhead full={d['overhead_full']:.2f}h "
          f"partial={d['overhead_partial']:.2f}h")

cfg = scaled(DLRM_KAGGLE, max_rows=5000)
ds = ClickLogDataset(cfg.table_sizes, num_samples=20000, seed=3)
print("\nMeasured (emulation, 2 failures x 25% shards):")
for pls in (0.02, 0.1, 0.2):
    mgr = CPRManager("cpr-ssu", p, cfg.table_sizes, target_pls=pls)
    inj = FailureInjector(2, 0.25, p.N_emb, p.T_total, seed=11)
    r = Emulator(cfg, ds, mgr, inj, batch_size=256).run()
    o = r.report["overheads"]
    print(f"  PLS={pls:<5} auc={r.auc:.4f} overhead={o['fraction'] * 100:.2f}% "
          f"measured_pls={r.report['measured_pls']:.4f}")
