"""Batched serving example: prefill + decode with a KV cache.

Loads a reduced config (any arch with a decode path), prefills a batch of
prompts, then decodes N tokens per prompt with the stacked per-layer caches,
reporting tokens/s.

  PYTHONPATH=src python examples/serve.py --arch gemma2-2b --tokens 64
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no decode path")
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    max_len = P + args.tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    state = T.init_decode_state(cfg, B, max_len, jnp.float32)
    step = jax.jit(lambda p, s, t, i: T.decode_step(p, s, t, i, cfg))

    # prefill via the decode path (teacher-forcing the prompt)
    t0 = time.time()
    for i in range(P):
        logits, state = step(params, state, prompts[:, i], jnp.int32(i))
    print(f"prefill: {P} steps in {time.time() - t0:.2f}s (incl. compile)")

    tok = jnp.argmax(logits, -1)
    out = [tok]
    t0 = time.time()
    for i in range(P, max_len - 1):
        logits, state = step(params, state, tok, jnp.int32(i))
        tok = jnp.argmax(logits, -1)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    n = len(out) * B
    print(f"decode: {n} tokens in {dt:.2f}s -> {n / dt:.1f} tok/s "
          f"(batch={B}, arch={cfg.name})")
    print("sample continuation ids:", [int(t[0]) for t in out[:12]])


if __name__ == "__main__":
    main()
