"""End-to-end driver: train a ~100M-param transformer LM for a few hundred
steps with CPR checkpointing + partial recovery of the embedding shards.

The model is a 12-layer gemma2-style decoder (d=512, ff=2048, 32k vocab,
~92M params).  Two failures are injected; CPR-MFU prioritizes saving the
most-frequently-seen token embeddings (Zipf-distributed synthetic corpus).

  PYTHONPATH=src python examples/train_lm_with_cpr.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs.base import ATTN, LOCAL_ATTN, ModelConfig
from repro.launch.train import train

CFG_100M = ModelConfig(
    name="lm-100m",
    arch_type="dense",
    num_layers=12,
    d_model=512,
    num_heads=8,
    num_kv_heads=4,
    head_dim=64,
    d_ff=2048,
    vocab_size=32768,
    block_pattern=(LOCAL_ATTN, ATTN),
    sliding_window=256,
    rope_theta=10000.0,
    act="silu",
    dtype="float32",
    source="gemma2-style demo config (~92M params)",
)

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="cpr-mfu")
    args = ap.parse_args()
    print(f"params ~= {CFG_100M.param_counts()['total'] / 1e6:.0f}M")
    _, hist = train(CFG_100M, steps=args.steps, batch=args.batch,
                    seq=args.seq, mode=args.mode, n_failures=2,
                    checkpoint_dir="artifacts/lm_ckpt")
    r = hist["report"]
    print(f"\nmode={r['mode']} effective={r['effective_mode']} "
          f"pls={r['measured_pls']:.4f} "
          f"bytes_written={r['bytes_written'] / 2 ** 20:.1f}MiB")
    print("loss trajectory:", [f"{s}:{l:.3f}" for s, l in hist["loss"]])
