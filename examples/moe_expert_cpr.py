"""CPR over MoE *expert* shards: the modern analogue of the paper's Emb PS.

DESIGN.md §4 argues the expert tables of an MoE are the best match for
CPR's frequency-prioritized partial checkpointing — the router assigns
Zipf-like traffic per expert, so MFU counters over *expert hits* prioritize
saving hot experts.  This example trains a reduced Qwen3-MoE, tracks router
assignments with the MFU tracker, and shows the hit histogram + which
experts a partial save would pick.

  PYTHONPATH=src python examples/moe_expert_cpr.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import trackers as trk
from repro.data.synthetic import TokenDataset
from repro.models import moe as moe_lib
from repro.models import transformer as T
from repro.optim.optimizers import apply_updates, get_optimizer

cfg = get_config("qwen3-moe-30b-a3b").reduced()
E = cfg.moe.num_experts
params = T.init_model(cfg, jax.random.PRNGKey(0))
opt = get_optimizer("adam", 1e-3)
ostate = opt.init(params)
ds = TokenDataset(cfg.vocab_size, num_tokens=200_000, seed=0)
counts = trk.mfu_init(E)


@jax.jit
def step(params, ostate, counts, batch):
    (loss, _), grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, batch, cfg), has_aux=True)(params)
    u, ostate = opt.update(grads, ostate, params)
    params = apply_updates(params, u)
    # router assignments of the first scanned MoE layer -> expert MFU
    x, pos = T.embed_inputs(params, batch, cfg)
    stage0 = jax.tree.map(lambda a: a[0], params["stages"][0])
    h = x.reshape(-1, cfg.d_model)
    logits = (h @ stage0["moe"]["router"]).astype(jnp.float32)
    _, top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k)
    counts = trk.mfu_update(counts, top_e)
    return params, ostate, counts, loss


for i, b in enumerate(ds.batches(4, 64, loop=True)):
    if i >= 30:
        break
    params, ostate, counts, loss = step(params, ostate, counts, b)

hist = np.asarray(counts)
order = np.argsort(hist)[::-1]
rn = max(1, int(0.5 * E))
save_ids, _ = trk.mfu_select(counts, rn)
print(f"expert hit histogram after 30 steps (E={E}, top_k={cfg.moe.top_k}):")
print("  hits:", hist.tolist())
print(f"  traffic skew: top expert {hist.max()} vs median "
      f"{int(np.median(hist))}")
print(f"  CPR-MFU would partial-save experts {sorted(np.asarray(save_ids).tolist())} "
      f"(r=0.5 -> {rn} of {E})")
print(f"final loss {float(loss):.3f}")
