"""Quickstart: train DLRM with CPR partial recovery under injected failures.

Runs the paper's core experiment end-to-end in ~1 minute on CPU:
full recovery vs CPR-MFU on a synthetic Criteo-like click log, with two
failures each clearing 25 % of the embedding-PS shards.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.dlrm import DLRM_KAGGLE, scaled
from repro.core import CPRManager, Emulator, FailureInjector, SystemParams
from repro.data.synthetic import ClickLogDataset

cfg = scaled(DLRM_KAGGLE, max_rows=5000)
ds = ClickLogDataset(cfg.table_sizes, num_samples=20000, seed=3)
params = SystemParams()          # production-projected failure/overhead model

print(f"{len(cfg.table_sizes)} embedding tables, "
      f"{cfg.total_emb_rows()} rows, CTR={ds.ctr:.3f}\n")

for mode in ("full", "cpr-mfu"):
    mgr = CPRManager(mode, params, cfg.table_sizes, target_pls=0.1)
    inj = FailureInjector(n_failures=2, fail_fraction=0.25,
                          n_shards=params.N_emb, T_total=params.T_total,
                          seed=11)
    result = Emulator(cfg, ds, mgr, inj, batch_size=256).run()
    print(result.summary())

print("\nCPR keeps the AUC of full recovery at ~1/15th of the checkpoint "
      "overhead (paper Fig. 7).")
